"""Delta-store ingest subsystem: O(delta) appends, merge-on-read scans,
threshold compaction, budgeted streaming ingest, epoch-keyed cache
survival, and WAL/delta crash recovery.

The differential harness is the spine: every query must be bit-identical
across {no-delta, delta-tail, post-compaction} layouts x budget matrix x
all three executors — the delta store is a *representation* change, never
a semantics change.
"""

import os
import threading

import numpy as np
import pytest

from repro.core import Col, ConflictError, startup
from repro.core.delta import (DeltaTable, compact, delta_append,
                              should_compact)
from repro.core.expression import Lit
from repro.core.table import Table

KB = 1 << 10
MB = 1 << 20

N = 8 * 2048                       # 8 imprint blocks
_rng = np.random.default_rng(42)
_DATA = {
    "k": (_rng.integers(0, 7, N)).astype(np.int64),
    "v": np.round(_rng.uniform(0.0, 100.0, N), 3),
    "ship": np.sort(_rng.integers(8000, 9200, N)).astype(np.int64),
    "tag": np.asarray([("red", "green", "blue")[i % 3]
                       for i in range(N)], dtype=object),
}


def _slice(lo, hi):
    return {c: v[lo:hi] for c, v in _DATA.items()}


def _mk_layout(layout, **kw):
    """One database per (layout, budget) cell.

    * eager   — the whole table in one create (delta-free control arm)
    * delta   — half the rows as base + three delta appends (tail alive)
    * compact — same appends under an always-compact threshold (folded)
    """
    frac = 1e-9 if layout == "compact" else 0.0
    db = startup(delta_compact_fraction=frac, **kw)
    if layout == "eager":
        db.create_table("t", _DATA)
        return db
    db.create_table("t", _slice(0, N // 2))
    for lo, hi in ((N // 2, 5 * N // 8), (5 * N // 8, 3 * N // 4),
                   (3 * N // 4, N)):
        db.append("t", _slice(lo, hi))
    t = db.catalog.table("t")
    if layout == "delta":
        assert isinstance(t, DeltaTable) and t.delta_rows == N // 2
    else:
        assert not isinstance(t, DeltaTable) and t.delta_rows == 0
    assert t.version == 3          # one version per append either way
    return db


QUERIES = {
    "group_agg": lambda db: (db.scan("t").group_by("k")
                             .agg(s=("sum", Col("v")), n=("count", None))),
    "filter_agg": lambda db: (db.scan("t")
                              .filter(Col("ship") <= Lit(8300))
                              .group_by("tag")
                              .agg(s=("sum", Col("v")), n=("count", None))),
}


def _pydict(q, distributed=False):
    return q.execute(distributed=distributed).to_pydict()


def _volcano_rows(db, plan):
    from repro.core.optimizer import optimize
    from repro.core.volcano import VolcanoExecutor
    return VolcanoExecutor(db).execute(optimize(plan, db.catalog))


def _assert_same(a, b, msg="", exact=True):
    assert set(a) == set(b), msg
    for c in a:
        av, bv = np.asarray(a[c]), np.asarray(b[c])
        if av.dtype == object or bv.dtype == object:
            assert list(map(str, av)) == list(map(str, bv)), f"{msg}:{c}"
        elif exact:
            np.testing.assert_array_equal(av, bv, err_msg=f"{msg}:{c}")
        else:
            # cross-executor: a sharded device sum associates floats
            # differently from the host loop — tolerance, not bits
            np.testing.assert_allclose(av.astype(float), bv.astype(float),
                                       rtol=1e-9, err_msg=f"{msg}:{c}")


# ---------------------------------------------------------------------------
# differential harness: layouts x budgets x executors
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qname", sorted(QUERIES))
@pytest.mark.parametrize("budget", [None, 128 * KB])
def test_layouts_bit_identical_all_executors(qname, budget):
    """Per executor, every layout is BIT-identical to the eager control arm
    (the delta store is a representation change); across executors results
    agree to float tolerance (shard-sum association differs by design)."""
    ref: dict[str, dict] = {}
    for layout in ("eager", "delta", "compact"):
        db = _mk_layout(layout, memory_budget=budget)
        try:
            q = QUERIES[qname](db)
            got = {"seq": _pydict(q),
                   "dist": _pydict(QUERIES[qname](db), distributed=True)}
            rows = _volcano_rows(db, q.plan)
            got["volcano"] = {c: [r[c] for r in rows] for c in got["seq"]}
            if not ref:
                ref = got
            for ex in ("seq", "dist", "volcano"):
                _assert_same(got[ex], ref[ex], f"{layout}/{ex}")
            _assert_same(got["dist"], got["seq"],
                         f"{layout}/dist-vs-seq", exact=False)
            _assert_same(got["volcano"], got["seq"],
                         f"{layout}/volcano-vs-seq", exact=False)
        finally:
            db.shutdown()


def test_delta_tail_visible_in_explain():
    db = _mk_layout("delta")
    try:
        txt = QUERIES["group_agg"](db).explain(physical=True)
        assert f"(delta: {N // 2} rows)" in txt
        assert "(delta:" not in QUERIES["group_agg"](
            _mk_layout("eager")).explain(physical=True)
    finally:
        db.shutdown()


# ---------------------------------------------------------------------------
# delta mechanics: O(delta) installs, VARCHAR recode vs rebase
# ---------------------------------------------------------------------------


class TestDeltaMechanics:
    def test_append_shares_base_object(self):
        db = startup(delta_compact_fraction=0.0)
        db.create_table("t", _slice(0, 1024))
        base_obj = db.catalog.table("t")
        db.append("t", _slice(1024, 1100))
        db.append("t", _slice(1100, 1200))
        t = db.catalog.table("t")
        assert isinstance(t, DeltaTable)
        assert t.base is base_obj              # base never copied
        assert (t.base_rows, t.delta_rows, t.delta_epoch) == (1024, 176, 2)
        assert t.version == 2 and t.base_version == 0
        db.shutdown()

    def test_merge_on_read_matches_eager(self):
        db = startup(delta_compact_fraction=0.0)
        db.create_table("t", _slice(0, 1000))
        db.append("t", _slice(1000, 1500))
        t = db.catalog.table("t")
        for c in _DATA:
            got = t.columns[c].to_numpy()
            want = np.asarray(_DATA[c][:1500])
            if got.dtype == object:
                assert list(map(str, got)) == list(map(str, want))
            else:
                np.testing.assert_array_equal(got, want)
        db.shutdown()

    def test_varchar_covered_values_stay_delta(self):
        # appended strings already in the base heap: recode, no rebase
        db = startup(delta_compact_fraction=0.0)
        db.create_table("t", _slice(0, 1024))
        db.append("t", {"k": np.array([1], dtype=np.int64),
                        "v": np.array([2.0]),
                        "ship": np.array([9000], dtype=np.int64),
                        "tag": np.asarray(["green"], dtype=object)})
        t = db.catalog.table("t")
        assert isinstance(t, DeltaTable)
        assert t.columns["tag"].heap is t.base.columns["tag"].heap
        assert str(t.columns["tag"].to_numpy()[-1]) == "green"
        db.shutdown()

    def test_varchar_novel_value_forces_rebase(self):
        # a novel string re-sorts the order-preserving heap, which would
        # recode the base's prefix — the append must rebase instead
        db = startup(delta_compact_fraction=0.0)
        db.create_table("t", _slice(0, 1024))
        db.append("t", {"k": np.array([1], dtype=np.int64),
                        "v": np.array([2.0]),
                        "ship": np.array([9000], dtype=np.int64),
                        "tag": np.asarray(["amber"], dtype=object)})
        t = db.catalog.table("t")
        assert not isinstance(t, DeltaTable)
        assert t.version == 1 and t.num_rows == 1025
        assert str(t.columns["tag"].to_numpy()[-1]) == "amber"
        db.shutdown()

    def test_schema_mismatch_raises(self):
        t = Table.from_dict("t", {"a": np.arange(4, dtype=np.int64)})
        bad = Table.from_dict("t", {"b": np.arange(4, dtype=np.int64)})
        with pytest.raises(ValueError, match="schema mismatch"):
            delta_append(t, bad)


# ---------------------------------------------------------------------------
# threshold compaction
# ---------------------------------------------------------------------------


class TestCompaction:
    def test_fold_is_version_and_content_identical(self):
        t = Table.from_dict("t", {"v": np.arange(100, dtype=np.int64)})
        d = delta_append(t, Table.from_dict(
            "t", {"v": np.arange(100, 130, dtype=np.int64)}))
        folded = compact(d)
        assert not isinstance(folded, DeltaTable)
        assert folded.version == d.version
        np.testing.assert_array_equal(folded.columns["v"].to_numpy(),
                                      np.arange(130))

    def test_threshold_policy(self):
        t = Table.from_dict("t", {"v": np.arange(100, dtype=np.int64)})
        d = delta_append(t, Table.from_dict(
            "t", {"v": np.arange(100, dtype=np.int64)}))
        assert not should_compact(t, 0.5)          # plain table: never
        assert not should_compact(d, 0.0)          # disabled knob
        assert should_compact(d, 1e-9)             # any tail trips ~0
        # budgeted: threshold is a fraction of memory_budget bytes
        tail_bytes = sum(c.nbytes for c in d.chunks)
        assert should_compact(d, 0.5, memory_budget=tail_bytes)
        assert not should_compact(d, 2.0, memory_budget=tail_bytes)

    def test_commit_hook_compacts_and_counts(self):
        db = startup(delta_compact_fraction=1e-9)
        db.create_table("t", _slice(0, 1024))
        db.append("t", _slice(1024, 1100))
        t = db.catalog.table("t")
        assert not isinstance(t, DeltaTable)       # folded under commit lock
        assert t.version == 1 and t.num_rows == 1100
        assert db.buffer_manager.stats.compactions == 1
        db.shutdown()

    def test_persistent_compaction_streams_and_gc_sweeps(self, tmp_path):
        db = startup(str(tmp_path / "d"), delta_compact_fraction=1e-9)
        db.create_table("t", {"v": np.arange(1000, dtype=np.int64)})
        db.checkpoint()
        db.append("t", {"v": np.arange(1000, 1500, dtype=np.int64)})
        t = db.catalog.table("t")
        assert not isinstance(t, DeltaTable)
        assert isinstance(t.columns["v"].data, np.memmap)   # streamed fold
        db.checkpoint()
        names = [f.name for f in (tmp_path / "d" / "data").iterdir()]
        assert not any(".v0." in n for n in names), names   # GC swept
        db.shutdown()
        db2 = startup(str(tmp_path / "d"))
        np.testing.assert_array_equal(
            db2.table("t").columns["v"].to_numpy(), np.arange(1500))
        db2.shutdown()


# ---------------------------------------------------------------------------
# budgeted streaming ingest
# ---------------------------------------------------------------------------


class TestIngest:
    def test_table_4x_budget_peak_under_budget(self):
        budget = 256 * KB
        rows = 4 * budget // 16                    # 16 B/row -> 4x budget
        db = startup(memory_budget=budget, delta_compact_fraction=0.0)

        def source():
            step = rows // 8
            for s in range(0, rows, step):
                yield {"a": np.arange(s, s + step, dtype=np.int64),
                       "b": np.arange(s, s + step, dtype=np.float64)}

        n = db.ingest("big", source())
        assert n == rows
        t = db.catalog.table("big")
        assert t.num_rows == rows
        assert t.nbytes >= 4 * budget
        assert db.buffer_manager.stats.peak <= budget
        np.testing.assert_array_equal(t.columns["a"].to_numpy(),
                                      np.arange(rows))
        db.shutdown()

    def test_ingest_with_compaction_stays_budgeted(self, tmp_path):
        budget = 256 * KB
        rows = 4 * budget // 16
        db = startup(str(tmp_path / "ing"), memory_budget=budget,
                     delta_compact_fraction=0.25)

        def source():
            step = rows // 8
            for s in range(0, rows, step):
                yield {"a": np.arange(s, s + step, dtype=np.int64),
                       "b": np.arange(s, s + step, dtype=np.float64)}

        assert db.ingest("big", source()) == rows
        assert db.buffer_manager.stats.peak <= budget
        assert db.buffer_manager.stats.compactions >= 1
        db.shutdown()
        db2 = startup(str(tmp_path / "ing"))
        t = db2.table("big")
        assert t.num_rows == rows
        np.testing.assert_array_equal(t.columns["a"].to_numpy(),
                                      np.arange(rows))
        db2.shutdown()

    def test_ingest_creates_table_with_varchar_heap_seed(self):
        db = startup(delta_compact_fraction=0.0)
        chunks = [{"s": np.asarray(["x", "y"], dtype=object),
                   "v": np.array([1.0, 2.0])},
                  {"s": np.asarray(["y", "x"], dtype=object),
                   "v": np.array([3.0, 4.0])}]
        assert db.ingest("t", iter(chunks)) == 4
        t = db.catalog.table("t")
        # first chunk seeded the heap, second appended as a delta
        assert isinstance(t, DeltaTable)
        assert list(map(str, t.columns["s"].to_numpy())) == \
            ["x", "y", "y", "x"]
        db.shutdown()


# ---------------------------------------------------------------------------
# epoch-keyed device-cache survival
# ---------------------------------------------------------------------------


class TestEpochCache:
    def _mkdb(self):
        db = startup(device_budget=256 * MB, device_batch_rows=4096,
                     delta_compact_fraction=0.0)
        n = 16384
        rng = np.random.default_rng(3)
        db.create_table("t", {
            "g": rng.integers(0, 5, n).astype(np.int64),
            "x": rng.standard_normal(n),
        })
        return db

    def _q(self, db):
        return db.scan("t").group_by("g").agg(s=("sum", Col("x")),
                                              n=("count", None))

    def test_repeat_scan_after_append_moves_tail_bytes_only(self):
        db = self._mkdb()
        try:
            self._q(db).execute(distributed=True)
            assert db.last_stats.device_tier != ""
            cold = db.last_stats.device_bytes_h2d
            assert cold > 0
            # warm repeat: fully cached, nothing moves
            self._q(db).execute(distributed=True)
            assert db.last_stats.device_bytes_h2d == 0
            db.append("t", {"g": np.array([1] * 64, dtype=np.int64),
                            "x": np.ones(64)})
            assert db.catalog.table("t").delta_rows == 64
            r = self._q(db).execute(distributed=True)
            st = db.last_stats
            assert st.device_tier != ""
            # only the one tail-overlapping batch re-uploads: 1 of 4+1
            # batches, so way under the cold full-table transfer
            assert 0 < st.device_bytes_h2d <= cold // 2
            assert st.device_bytes_h2d == st.delta_bytes_h2d
            assert st.delta_rows == 64
            # and the appended rows are in the answer
            d = r.to_pydict()
            got = dict(zip(d["g"], d["n"]))
            assert sum(got.values()) == 16384 + 64
        finally:
            db.shutdown()

    def test_delta_keys_die_on_next_append_base_keys_survive(self):
        db = self._mkdb()
        try:
            db.append("t", {"g": np.array([1] * 64, dtype=np.int64),
                            "x": np.ones(64)})
            self._q(db).execute(distributed=True)
            from repro.core.device_cache import _is_delta_key
            with db.device_manager._lock:
                keys = list(db.device_manager._blocks)
            n_delta = sum(1 for k in keys if _is_delta_key(k))
            n_base = len(keys) - n_delta
            assert n_delta > 0 and n_base > 0
            db.append("t", {"g": np.array([2] * 64, dtype=np.int64),
                            "x": np.ones(64)})
            with db.device_manager._lock:
                keys2 = list(db.device_manager._blocks)
            assert sum(1 for k in keys2 if _is_delta_key(k)) == 0
            assert len(keys2) == n_base
        finally:
            db.shutdown()


# ---------------------------------------------------------------------------
# imprints: update-on-append, not invalidate
# ---------------------------------------------------------------------------


class TestImprintExtension:
    def test_append_extends_instead_of_rebuilding(self):
        db = startup(delta_compact_fraction=0.0)
        db.create_table("t", _slice(0, 3 * 2048 + 100))
        imp0 = db.index_manager.get_imprint("t", "ship")
        built = db.index_manager.stats_built
        db.append("t", _slice(3 * 2048 + 100, N))
        imp1 = db.index_manager.get_imprint("t", "ship")
        assert db.index_manager.stats_built == built    # no rebuild
        assert imp1.n_rows == N
        # complete blocks of the old prefix are byte-identical
        keep = imp0.n_rows // imp0.block
        np.testing.assert_array_equal(imp1.mins[:keep], imp0.mins[:keep])
        np.testing.assert_array_equal(imp1.maxs[:keep], imp0.maxs[:keep])
        np.testing.assert_array_equal(imp1.bitmaps[:keep],
                                      imp0.bitmaps[:keep])
        db.shutdown()

    def test_extended_imprint_prunes_soundly(self):
        db = startup(delta_compact_fraction=0.0)
        db.create_table("t", _slice(0, 3 * 2048))
        db.index_manager.get_imprint("t", "ship")
        db.append("t", _slice(3 * 2048, N))
        ship = _DATA["ship"]
        for lo, hi in ((8000, 8100), (8500, 8600),
                       (int(ship.max()) - 5, int(ship.max()) + 5)):
            mask, _ = db.index_manager.imprint_mask(
                "t", "ship", lo, hi, False, False)
            want = (ship >= lo) & (ship <= hi)
            np.testing.assert_array_equal(mask, want, err_msg=f"{lo}-{hi}")
        db.shutdown()

    def test_out_of_range_appends_stay_sound(self):
        # appended values beyond the original (lo, hi) clip into the edge
        # bins — the bitmap stays a superset, mins/maxs stay exact
        db = startup(delta_compact_fraction=0.0)
        n = 3 * 2048
        db.create_table("t", {"v": np.arange(n, dtype=np.float64)})
        imp0 = db.index_manager.get_imprint("t", "v")
        db.append("t", {"v": np.array([1e6, -1e6])})
        imp1 = db.index_manager.get_imprint("t", "v")
        assert (imp1.lo, imp1.hi) == (imp0.lo, imp0.hi)
        mask, _ = db.index_manager.imprint_mask(
            "t", "v", 1e6 - 1, 1e6 + 1, False, False)
        assert mask.sum() == 1 and mask[-2]
        db.shutdown()


# ---------------------------------------------------------------------------
# concurrency: N appenders + M readers, prefix-consistent reads
# ---------------------------------------------------------------------------


CHUNK = 64


def test_concurrent_appenders_and_readers():
    """Every read must be bit-identical to SOME committed prefix: chunks
    are atomic (no torn reads) and each thread's chunks appear in order."""
    db = startup(delta_compact_fraction=0.25)
    db.create_table("t", {"v": np.empty(0, dtype=np.int64)})
    n_appenders, n_chunks = 4, 12
    stop = threading.Event()
    errors: list = []

    def appender(tid):
        try:
            for seq in range(n_chunks):
                val = tid * 1000 + seq
                while True:
                    try:
                        db.append("t", {"v": np.full(CHUNK, val,
                                                     dtype=np.int64)})
                        break
                    except ConflictError:
                        continue      # first-committer-wins: retry
        except Exception as e:        # pragma: no cover - failure capture
            errors.append(e)

    def reader():
        try:
            while not stop.is_set():
                t = db.catalog.table("t")
                v = t.columns["v"].to_numpy()
                assert len(v) % CHUNK == 0, "torn chunk visible"
                seen: dict[int, list[int]] = {}
                for i in range(0, len(v), CHUNK):
                    block = v[i:i + CHUNK]
                    assert (block == block[0]).all(), "interleaved chunk"
                    seen.setdefault(int(block[0]) // 1000,
                                    []).append(int(block[0]) % 1000)
                for tid, seqs in seen.items():
                    assert sorted(seqs) == list(range(len(seqs))), \
                        f"thread {tid} chunks out of prefix order: {seqs}"
        except Exception as e:        # pragma: no cover - failure capture
            errors.append(e)

    readers = [threading.Thread(target=reader) for _ in range(2)]
    appenders = [threading.Thread(target=appender, args=(i,))
                 for i in range(n_appenders)]
    for th in readers + appenders:
        th.start()
    for th in appenders:
        th.join(60)
    stop.set()
    for th in readers:
        th.join(60)
    assert not errors, errors
    t = db.catalog.table("t")
    assert t.num_rows == n_appenders * n_chunks * CHUNK
    db.shutdown()


def test_replace_append_write_write_conflict(db):
    # DELETE (replace) and append race: first committer wins, per table
    db.create_table("t", {"v": np.arange(10, dtype=np.int64)})
    t1 = db.txn_manager.begin(db)
    t2 = db.txn_manager.begin(db)
    keep = np.arange(5)
    old = t1.snapshot["t"]
    t1.replace("t", Table(old.schema,
                          {c: col.take(keep)
                           for c, col in old.columns.items()},
                          version=old.version + 1))
    t2.append("t", Table.from_dict("t", {"v": np.array([99],
                                                       dtype=np.int64)}))
    t1.commit()
    with pytest.raises(ConflictError):
        t2.commit()
    assert db.table("t").num_rows == 5


def test_delete_conflict_leaves_no_open_txn(db, monkeypatch):
    """Session.delete routes through begin/commit/rollback: a conflicting
    concurrent writer aborts the delete cleanly — no leaked open
    transaction, no poked TransactionManager internals, engine usable."""
    from repro.core import transactions as tx
    db.create_table("t", {"v": np.arange(10, dtype=np.int64)})
    created: list = []
    real_begin = db.txn_manager.begin

    def spy_begin(database):
        t = real_begin(database)
        created.append(t)
        return t

    monkeypatch.setattr(db.txn_manager, "begin", spy_begin)
    real_replace = tx.Transaction.replace

    def racing_replace(self, name, table):
        # a concurrent append commits between the delete's begin and commit
        monkeypatch.setattr(tx.Transaction, "replace", real_replace)
        db.append("t", {"v": np.array([99], dtype=np.int64)})
        return real_replace(self, name, table)

    monkeypatch.setattr(tx.Transaction, "replace", racing_replace)
    with pytest.raises(ConflictError):
        db.delete("t", Col("v") >= 5)
    # created[0] is the delete's txn (the racing append begins created[1])
    assert created[0].state == "aborted"         # rolled back, not leaked
    # the engine still serves writes and deletes afterwards
    assert db.table("t").num_rows == 11
    assert db.delete("t", Col("v") >= 5) == 6
    assert db.table("t").num_rows == 5


# ---------------------------------------------------------------------------
# WAL / delta crash-recovery matrix
# ---------------------------------------------------------------------------


def _crash(db):
    """Simulate a process crash (idiom from test_storage_txn)."""
    with __import__("repro.core.session",
                    fromlist=["_open_lock"])._open_lock:
        from repro.core.session import _open_dirs
        _open_dirs.clear()
    db.storage.release_lock()


class TestCrashRecovery:
    def _seed(self, path, frac=0.0):
        db = startup(str(path), delta_compact_fraction=frac)
        db.create_table("t", {"a": np.arange(1000, dtype=np.int64),
                              "s": np.asarray(["x", "y"] * 500,
                                              dtype=object)})
        db.checkpoint()
        return db

    def _append(self, db, lo, hi, s="x"):
        db.append("t", {"a": np.arange(lo, hi, dtype=np.int64),
                        "s": np.asarray([s] * (hi - lo), dtype=object)})

    def test_delta_appends_replay_as_deltas(self, tmp_path):
        db = self._seed(tmp_path / "d1")
        self._append(db, 1000, 1100)
        self._append(db, 1100, 1250)
        _crash(db)
        db2 = startup(str(tmp_path / "d1"), delta_compact_fraction=0.0)
        t = db2.table("t")
        assert isinstance(t, DeltaTable)      # O(delta) replay, same layout
        assert (t.base_rows, t.delta_epoch) == (1000, 2)
        assert t.num_rows == 1250 and t.version == 2
        np.testing.assert_array_equal(t.columns["a"].to_numpy(),
                                      np.arange(1250))
        db2.shutdown()

    def test_torn_wal_tail_replays_prefix(self, tmp_path):
        db = self._seed(tmp_path / "d2")
        self._append(db, 1000, 1100)
        _crash(db)
        wal = tmp_path / "d2" / "wal" / "wal.jsonl"
        wal.write_bytes(wal.read_bytes() + b'{"seq": 9, "table": "t"')
        db2 = startup(str(tmp_path / "d2"))
        assert db2.table("t").num_rows == 1100
        db2.shutdown()

    def test_crash_after_compaction_recovers(self, tmp_path):
        db = self._seed(tmp_path / "d3", frac=1e-9)
        self._append(db, 1000, 1200)          # triggers fold + catalog write
        t = db.catalog.table("t")
        assert not isinstance(t, DeltaTable)
        _crash(db)
        db2 = startup(str(tmp_path / "d3"))
        t = db2.table("t")
        assert t.num_rows == 1200
        np.testing.assert_array_equal(t.columns["a"].to_numpy(),
                                      np.arange(1200))
        db2.shutdown()

    def test_varchar_rebase_in_replay(self, tmp_path):
        # a novel string in the WAL chunk forces a rebase during replay —
        # content must match regardless of representation
        db = self._seed(tmp_path / "d4")
        self._append(db, 1000, 1050, s="z")   # novel: rebase on commit
        self._append(db, 1050, 1080, s="x")   # covered: delta again
        _crash(db)
        db2 = startup(str(tmp_path / "d4"), delta_compact_fraction=0.0)
        t = db2.table("t")
        assert t.num_rows == 1080
        got = t.columns["s"].to_numpy()
        assert str(got[1000]) == "z" and str(got[-1]) == "x"
        db2.shutdown()

    def test_checkpoint_folds_and_reopens_plain(self, tmp_path):
        db = self._seed(tmp_path / "d5")
        self._append(db, 1000, 1100)
        db.checkpoint()                       # WAL folded into column files
        wal = tmp_path / "d5" / "wal" / "wal.jsonl"
        assert not wal.exists() or wal.stat().st_size == 0
        _crash(db)
        db2 = startup(str(tmp_path / "d5"))
        assert db2.table("t").num_rows == 1100
        db2.shutdown()
