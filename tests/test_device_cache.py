"""Device-tier column cache (core/device_cache.py + DistributedScanAgg).

Contracts under test:

* the device budget matrix (unbudgeted / generous / tight) is
  **bit-identical** over TPC-H Q1-shaped aggregates — the batch
  decomposition, not the budget, fixes the arithmetic; budgets only change
  transfer/caching behaviour — with ``device_bytes_peak <= device_budget``
  in every budgeted cell and LRU evictions in the tight cell;
* a repeated scan is served from the cross-query cache: second run has
  ``device_cache_hits > 0`` and moves **zero** new host→device bytes;
* inputs that don't fit even one morsel batch fall back to the host tier
  (same results, no device traffic);
* DeviceBufferManager unit behaviour: LRU order, pin protection, dirty
  writeback + transparent re-upload, invalidation, budget validation.
"""

import numpy as np
import pytest

from repro.core import Col, DateLit, startup
from repro.core.device_cache import (DeviceBlockKeys, DeviceBudgetError,
                                     DeviceBufferManager)

BATCH = 4096              # fixed across cells: identical batching -> bits
GENEROUS = 64 << 20
TIGHT = 512 << 10         # > 2 batch working sets, < the table: streams
TINY = 8 << 10            # < one batch working set: host fallback


@pytest.fixture(scope="module")
def lineitem():
    from repro.data import tpch
    return tpch.generate(0.01)["lineitem"]


def _mkdb(lineitem, device_budget, **kw):
    li, types, scales = lineitem
    db = startup(device_budget=device_budget, device_batch_rows=BATCH, **kw)
    db.create_table("lineitem", li, types, scales)
    return db


def _q1(db):
    """TPC-H Q1 shape: filter + dense VARCHAR group keys + the full agg
    mix (sum / avg / count / min / max)."""
    return (db.scan("lineitem")
            .filter(Col("l_shipdate") <= DateLit("1998-09-02"))
            .group_by("l_returnflag", "l_linestatus")
            .agg(sum_qty=("sum", Col("l_quantity")),
                 sum_base_price=("sum", Col("l_extendedprice")),
                 avg_qty=("avg", Col("l_quantity")),
                 min_qty=("min", Col("l_quantity")),
                 max_disc=("max", Col("l_discount")),
                 count_order=("count", None)))


def _run(db):
    return _q1(db).execute(distributed=True).to_pydict()


def _assert_bits(a: dict, b: dict, ctx: str):
    assert list(a) == list(b), ctx
    for c in a:
        av, bv = np.asarray(a[c]), np.asarray(b[c])
        if av.dtype == object:
            assert list(map(str, av)) == list(map(str, bv)), (ctx, c)
        else:
            np.testing.assert_array_equal(av, bv, err_msg=f"{ctx} col={c}")


# ---------------------------------------------------------------------------
# budget matrix: bit-identity + peak <= budget + evictions when tight
# ---------------------------------------------------------------------------


def test_device_budget_matrix_bit_identical(lineitem):
    cells = {}
    stats = {}
    tiers = {}
    for budget in (None, GENEROUS, TIGHT):
        db = _mkdb(lineitem, budget)
        cells[budget] = _run(db)
        stats[budget] = db.buffer_manager.stats
        tiers[budget] = db.last_stats.device_tier
        assert db.last_stats.device_tier in ("resident", "streamed"), \
            "Q1 must run on the device tier in every cell"
    for budget in (GENEROUS, TIGHT):
        _assert_bits(cells[None], cells[budget], f"device_budget={budget}")
        st = stats[budget]
        assert st.device_bytes_peak <= budget, (st.device_bytes_peak, budget)
    # tight cell: the table outgrows the budget -> streamed with eviction
    assert tiers[TIGHT] == "streamed"
    assert stats[TIGHT].device_evictions > 0
    # generous cell: fully resident, nothing evicted
    assert tiers[GENEROUS] == "resident"
    assert stats[GENEROUS].device_evictions == 0


def test_device_matches_sequential(lineitem):
    db = _mkdb(lineitem, TIGHT)
    seq = _q1(db).execute().to_pydict()
    dev = _run(db)
    for c in seq:
        a, b = np.asarray(seq[c]), np.asarray(dev[c])
        if a.dtype == object:
            assert list(map(str, a)) == list(map(str, b))
        else:
            np.testing.assert_allclose(a.astype(float), b.astype(float),
                                       rtol=1e-9)


def test_streamed_prefetch_overlaps(lineitem):
    """Streaming issues batch N+1's transfer ahead of use."""
    db = _mkdb(lineitem, TIGHT)
    _run(db)
    assert db.last_stats.device_prefetch_hits > 0
    assert db.buffer_manager.stats.device_prefetch_hits > 0


# ---------------------------------------------------------------------------
# cross-query cache: repeat scans skip the host→device transfer
# ---------------------------------------------------------------------------


def test_repeated_query_hits_cache_no_new_h2d(lineitem):
    db = _mkdb(lineitem, GENEROUS)
    first = _run(db)
    s1 = db.last_stats
    assert s1.device_bytes_h2d > 0          # cold: base columns transferred
    assert s1.device_cache_hits == 0
    second = _run(db)
    s2 = db.last_stats
    assert s2.device_cache_hits > 0
    assert s2.device_bytes_h2d == 0, \
        "cached base columns must not be re-transferred"
    _assert_bits(first, second, "repeat")


def test_unbudgeted_does_not_retain_blocks(lineitem):
    """device_budget=None is zero-config: no silent device-memory growth —
    query blocks are dropped on completion."""
    db = _mkdb(lineitem, None)
    _run(db)
    assert db.device_manager.resident_blocks == 0
    assert db.last_stats.device_tier == "resident"


def test_appended_version_invalidates_cache(lineitem):
    """Keys carry the table version: appending produces a new version whose
    blocks miss the cache (no stale reads)."""
    li, types, scales = lineitem
    db = _mkdb(lineitem, GENEROUS)
    base = _run(db)
    one = {c: np.asarray(v[:1]) for c, v in li.items()}
    db.append("lineitem", one, types, scales)
    bumped = _run(db)
    assert db.last_stats.device_bytes_h2d > 0     # new version: fresh blocks
    n0 = np.asarray(base["count_order"], dtype=np.int64).sum()
    n1 = np.asarray(bumped["count_order"], dtype=np.int64).sum()
    assert n1 == n0 + 1


# ---------------------------------------------------------------------------
# host fallback: inputs the device tier cannot place
# ---------------------------------------------------------------------------


def test_tiny_budget_falls_back_to_host(lineitem):
    db = _mkdb(lineitem, TINY)
    res = _run(db)
    assert db.last_stats.device_tier == ""        # routed to the host tier
    assert db.buffer_manager.stats.device_bytes_h2d == 0
    ref = _q1(db).execute().to_pydict()
    _assert_bits(ref, res, "fallback")


# ---------------------------------------------------------------------------
# DeviceBufferManager unit behaviour
# ---------------------------------------------------------------------------


def _blk(i, n=1024):
    return np.full(n, i, dtype=np.float64)        # 8 KiB per block


def test_lru_eviction_order():
    m = DeviceBufferManager(budget=3 * 8192)
    for i in range(3):
        m.put(("t", "c", 0, i), _blk(i))
    assert m.get(("t", "c", 0, 0)) is not None    # bump 0 to most-recent
    m.put(("t", "c", 0, 3), _blk(3))              # evicts LRU: block 1
    assert ("t", "c", 0, 1) not in m
    assert ("t", "c", 0, 0) in m and ("t", "c", 0, 2) in m
    assert m.stats.device_evictions == 1
    assert m.stats.device_bytes_peak <= 3 * 8192


def test_pinned_blocks_never_evicted():
    m = DeviceBufferManager(budget=2 * 8192)
    m.put(("t", "c", 0, 0), _blk(0), pin=True)
    m.put(("t", "c", 0, 1), _blk(1), pin=True)
    with pytest.raises(DeviceBudgetError):
        m.put(("t", "c", 0, 2), _blk(2))
    m.unpin(("t", "c", 0, 0))
    m.put(("t", "c", 0, 2), _blk(2))              # now block 0 can go
    assert ("t", "c", 0, 0) not in m
    assert m.resident_bytes <= 2 * 8192


def test_oversized_block_rejected():
    m = DeviceBufferManager(budget=4096)
    with pytest.raises(DeviceBudgetError):
        m.put(("t", "c", 0, 0), _blk(0))


def test_dirty_writeback_roundtrip():
    """Evicted intermediates are copied back to host and transparently
    re-uploaded on next use — bit-exact."""
    import jax
    jax.config.update("jax_enable_x64", True)     # the engine's dtype mode
    m = DeviceBufferManager(budget=2 * 8192)
    vals = np.linspace(-1.0, 1.0, 1024)
    dev = jax.device_put(vals)
    m.adopt(("#q", "carry", 1, 0), dev, dirty=True)
    m.put(("t", "c", 0, 0), _blk(0))
    m.put(("t", "c", 0, 1), _blk(1))              # pressure: carry evicted
    assert m.stats.device_writebacks == 1
    assert ("#q", "carry", 1, 0) not in m
    back = m.get(("#q", "carry", 1, 0))           # re-upload from host copy
    assert back is not None
    np.testing.assert_array_equal(np.asarray(back), vals)


def test_clean_eviction_drops_without_writeback():
    m = DeviceBufferManager(budget=8192)
    m.put(("t", "c", 0, 0), _blk(0))
    m.put(("t", "c", 0, 1), _blk(1))
    assert m.stats.device_writebacks == 0
    assert m.get(("t", "c", 0, 0)) is None        # clean: host has the data


def test_invalidate_table():
    m = DeviceBufferManager(budget=None)
    m.put(DeviceBlockKeys.column("a", "x", 0, 0), _blk(0))
    m.put(DeviceBlockKeys.column("b", "x", 0, 0), _blk(1))
    m.invalidate_table("a")
    assert DeviceBlockKeys.column("a", "x", 0, 0) not in m
    assert DeviceBlockKeys.column("b", "x", 0, 0) in m
    assert m.resident_bytes == 8192


def test_cache_hit_accounting():
    m = DeviceBufferManager(budget=None)
    key = DeviceBlockKeys.column("t", "x", 3, 7)
    m.put(key, _blk(0))
    assert m.stats.device_cache_hits == 0
    assert m.get(key) is not None
    assert m.get(key) is not None
    assert m.stats.device_cache_hits == 2
    assert m.stats.device_bytes_h2d == 8192       # one transfer only


def test_budget_validation():
    with pytest.raises(ValueError):
        DeviceBufferManager(budget=0)
    with pytest.raises(ValueError):
        DeviceBufferManager(budget=-1)


def test_carry_eviction_mid_query_reuploads(lineitem, monkeypatch):
    """Force the merge carry (the only dirty block a query owns) out of the
    cache after every batch: the streaming loop must write it back, re-
    upload it, and still produce bit-identical results."""
    from repro.core import device_cache
    baseline = _run(_mkdb(lineitem, TIGHT))

    orig_adopt = device_cache.DeviceBufferManager.adopt

    def evicting_adopt(self, key, arr, **kw):
        out = orig_adopt(self, key, arr, **kw)
        if key[0] == device_cache.CARRY_TABLE and self.budget is not None:
            with self._lock:
                blk = self._blocks.get(key)
                if blk is not None and blk.pins == 0:
                    self._evict(key)              # budget-pressure stand-in
        return out

    monkeypatch.setattr(device_cache.DeviceBufferManager, "adopt",
                        evicting_adopt)
    db = _mkdb(lineitem, TIGHT)
    res = _run(db)
    st = db.buffer_manager.stats
    assert db.last_stats.device_tier == "streamed", \
        "carry churn must not knock the query off the device tier"
    assert st.device_writebacks > 0
    assert st.device_bytes_peak <= TIGHT
    _assert_bits(baseline, res, "carry-evict")


def test_cache_keys_include_batch_geometry(lineitem):
    """Two slicings of the same column version are distinct blocks: a
    second query with different batch geometry must not get cache hits on
    the first one's blocks (it would aggregate the wrong row ranges)."""
    from repro.core.parallel import DistributedScanAgg, match_scan_agg
    from repro.core.optimizer import optimize
    db = _mkdb(lineitem, GENEROUS)
    ref = _q1(db).execute().to_pydict()          # host-tier reference
    plan = optimize(_q1(db).plan, db.catalog)
    spec = match_scan_agg(plan, db.catalog)
    import jax
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()).reshape(-1), ("data",))
    outs = {}
    for m in (1536, 2560):                       # different row slicings
        agg = DistributedScanAgg(db, spec, mesh, batch_rows=m)
        outs[m] = agg.run()
    np.testing.assert_allclose(outs[1536], outs[2560], rtol=1e-9)
    # and both agree with the host tier (wrong-rows bugs show up here)
    cnt = {m: np.sort(o[:, -1][o[:, -1] > 0]) for m, o in outs.items()}
    ref_cnt = np.sort(np.asarray(ref["count_order"], dtype=np.float64))
    for m in outs:
        np.testing.assert_array_equal(cnt[m], ref_cnt)


def test_snapshot_namespace_prevents_stale_hits():
    """A transaction snapshot's table reuses the version number the next
    committed write will get; its device blocks live under a unique key
    namespace in the SHARED manager (one budget), so later committed-data
    queries can never hit the snapshot's (possibly rolled-back) rows."""
    from repro.core.optimizer import optimize
    from repro.core.parallel import DistributedScanAgg, match_scan_agg
    import jax
    from jax.sharding import Mesh
    n = 8192
    db = startup(device_budget=64 << 20, device_batch_rows=4096)
    db.create_table("t", {"g": (np.arange(n) % 5).astype(np.int64),
                          "x": np.ones(n)})
    mesh = Mesh(np.array(jax.devices()).reshape(-1), ("data",))

    def _agg(d):
        plan = optimize(d.scan("t").group_by("g").agg(s=("sum", "x")).plan,
                        d.catalog)
        spec = match_scan_agg(plan, d.catalog)
        out = DistributedScanAgg(d, spec, mesh).run()
        return out[:, 0]                        # per-group sums

    # snapshot view: same table name at the version the next commit gets
    # (version 1), but with DIFFERENT data — exactly a txn's uncommitted
    # append — sharing the parent's device manager under its own namespace
    snap = startup()
    snap.catalog.tables["t"] = db.table("t").append_table(
        db.table("t"))                          # version 1, 2n rows
    snap.device_manager = db.device_manager
    snap.device_key_namespace = 7
    snap_sums = _agg(snap)
    assert snap_sums.sum() == 2 * n
    db.device_manager.invalidate_namespace(7)
    assert not any(isinstance(k[2], tuple) and k[2][0] == 7
                   for k in db.device_manager._blocks)
    # the real commit: version 1 on the parent, one extra row
    db.append("t", {"g": np.array([0], dtype=np.int64),
                    "x": np.array([1.0])})
    assert db.table("t").version == 1
    sums = _agg(db)
    assert sums.sum() == n + 1, \
        "committed-version query must not hit the snapshot's blocks"


def test_heap_renumber_invalidates_step_cache():
    """VARCHAR literal codes are baked into jitted traces; an append that
    introduces a novel string renumbers the whole heap, so the compiled
    step must not be reused (its key includes the heap fingerprint)."""
    rng = np.random.default_rng(5)
    n = 20_000
    cities = np.asarray(["nyc", "sfo"], dtype=object)[rng.integers(0, 2, n)]
    db = startup(device_budget=64 << 20, device_batch_rows=4096)
    db.create_table("t", {"city": cities,
                          "hour": rng.integers(0, 8, n).astype(np.int64),
                          "x": rng.uniform(0, 1, n)})

    def q():
        return (db.scan("t").filter(Col("city") == "nyc")
                .group_by("hour").agg(s=("sum", "x"), c=("count", None)))

    r1 = q().execute(distributed=True).to_pydict()
    assert db.last_stats.device_tier != ""
    np.testing.assert_array_equal(
        np.asarray(r1["c"], np.int64), np.asarray(
            q().execute().to_pydict()["c"], np.int64))
    # novel string sorting BEFORE "nyc": merge renumbers every code
    db.append("t", {"city": np.asarray(["ams"], dtype=object),
                    "hour": np.array([0], dtype=np.int64),
                    "x": np.array([0.5])})
    r2 = q().execute(distributed=True).to_pydict()
    seq = q().execute().to_pydict()
    np.testing.assert_array_equal(np.asarray(r2["c"], np.int64),
                                  np.asarray(seq["c"], np.int64))
    np.testing.assert_allclose(np.asarray(r2["s"], float),
                               np.asarray(seq["s"], float), rtol=1e-9)


def test_mixed_meshes_share_database_without_fallback(lineitem):
    """Block keys carry mesh identity: blocks cached for one mesh must not
    be served to a query on another mesh (the jitted step would raise on
    incompatible device placement and silently fall off the device tier)."""
    import jax
    from jax.sharding import Mesh
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs a multi-device backend (CI forces 4)")
    db = _mkdb(lineitem, GENEROUS)
    mesh_all = Mesh(np.array(devs).reshape(-1), ("data",))
    mesh_one = Mesh(np.array(devs[:1]).reshape(-1), ("data",))
    plan = _q1(db).plan
    a = db.execute_plan(plan, distributed=True, mesh=mesh_all).to_pydict()
    assert db.last_stats.device_tier != ""
    b = db.execute_plan(plan, distributed=True, mesh=mesh_one).to_pydict()
    assert db.last_stats.device_tier != "", \
        "second mesh must run on the device tier, not fall back"
    for c in a:
        av, bv = np.asarray(a[c]), np.asarray(b[c])
        if av.dtype == object:
            assert list(map(str, av)) == list(map(str, bv))
        else:
            np.testing.assert_allclose(av.astype(float), bv.astype(float),
                                       rtol=1e-9)


def test_append_keeps_base_blocks_replace_frees_all(lineitem):
    """Delta-store cache lifecycle: an append lands as a delta chunk, so the
    immutable base's device blocks SURVIVE it (epoch-keyed caching — only
    tail-overlapping entries are invalidated), while a DELETE rewrites rows
    and must still free every block of the table."""
    li, types, scales = lineitem
    db = _mkdb(lineitem, GENEROUS)
    _run(db)
    before = db.device_manager.resident_blocks
    assert before > 0
    db.append("lineitem", {c: np.asarray(v[:1]) for c, v in li.items()},
              types, scales)
    t = db.catalog.table("lineitem")
    assert t.delta_rows == 1           # the append took the delta path
    assert db.device_manager.resident_blocks == before, \
        "base-version blocks must survive a delta append"
    db.delete("lineitem", Col("l_quantity") >= 0)
    assert db.device_manager.resident_blocks == 0


# ---------------------------------------------------------------------------
# get_or_put under builder failure (multi-thread stress)
# ---------------------------------------------------------------------------


class TestGetOrPutBuilderFailure:
    def test_stress_builder_raises_mid_upload(self):
        """Hammer one key from many threads while the builder fails on a
        schedule: failed builds must not poison attachers (they retry as
        builders), must not leak pinned bytes, and the budget invariant
        ``device_bytes_peak <= device_budget`` must hold throughout."""
        import threading

        block = np.ones(4096, dtype=np.float64)            # 32 KiB
        budget = 4 * block.nbytes
        dm = DeviceBufferManager(budget=budget)
        key = ("#stress", "c", 0, 0)
        counter = threading.Lock()
        attempts = [0]

        def build():
            with counter:
                attempts[0] += 1
                n = attempts[0]
            if n % 3 == 1:          # every third build dies mid-upload
                raise RuntimeError("upload failed")
            return block

        successes, failures, errors = [], [], []

        def worker():
            try:
                for i in range(40):
                    try:
                        arr = dm.get_or_put(key, build, pin=True)
                        assert float(np.asarray(arr)[0]) == 1.0
                        successes.append(1)
                        assert dm.resident_bytes <= budget
                        dm.unpin(key)
                    except RuntimeError:
                        failures.append(1)   # this thread was the builder
                    if i % 10 == 9:
                        dm.drop(key)         # force periodic rebuilds
            except Exception as e:           # noqa: BLE001
                errors.append(e)

        ts = [threading.Thread(target=worker) for _ in range(6)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(120)
        assert not errors, errors
        assert successes, "no thread ever completed a get_or_put"
        assert failures, "the failure schedule never fired"
        assert dm.stats.device_bytes_peak <= budget
        dm.drop(key)
        # a failed build must leave nothing behind: no block, no pinned
        # bytes, no residual accounting
        assert dm.resident_bytes == 0
        assert dm.resident_blocks == 0

    def test_builder_failure_leaves_no_flight_slot(self):
        """After a failed build the single-flight table is empty — the
        next caller becomes a fresh builder, it does not attach to a dead
        flight."""
        dm = DeviceBufferManager(budget=1 << 20)
        key = ("#once", "c", 0, 0)

        def boom():
            raise RuntimeError("no")

        with pytest.raises(RuntimeError):
            dm.get_or_put(key, boom, pin=True)
        assert len(dm._flight._calls) == 0
        assert dm.resident_bytes == 0
        arr = dm.get_or_put(key, lambda: np.arange(8.0), pin=False)
        assert np.asarray(arr).shape == (8,)
