"""Persistence (memmap columns, WAL, recovery) + optimistic concurrency."""

import json
import os

import numpy as np
import pytest

from repro.core import ConflictError, DatabaseError, startup
from repro.core.session import Database


def _mkdb(path):
    db = startup(str(path))
    db.create_table("t", {"a": np.arange(100, dtype=np.int64),
                          "s": np.asarray(["x", "y"] * 50, dtype=object),
                          "d": np.arange(100) * 1.5})
    return db


def test_persist_and_reload(tmp_path):
    db = _mkdb(tmp_path / "db1")
    db.shutdown()
    db2 = startup(str(tmp_path / "db1"))
    t = db2.table("t")
    assert t.num_rows == 100
    assert list(t.columns["s"].to_numpy()[:2]) == ["x", "y"]
    # memmap-backed (the paper's mmap storage model)
    assert isinstance(t.columns["a"].data, np.memmap)
    db2.shutdown()


def test_wal_replay_after_crash(tmp_path):
    db = _mkdb(tmp_path / "db2")
    db.checkpoint()
    # bulk append goes to the WAL; simulate crash: NO shutdown/checkpoint
    db.append("t", {"a": np.array([999], dtype=np.int64),
                    "s": np.asarray(["z"], dtype=object),
                    "d": np.array([9.9])})
    with __import__("repro.core.session", fromlist=["_open_lock"])._open_lock:
        from repro.core.session import _open_dirs
        _open_dirs.clear()                      # drop the lock, not the data
    db.storage.release_lock()                   # a crash closes the flock fd
    db2 = startup(str(tmp_path / "db2"))
    t = db2.table("t")
    assert t.num_rows == 101
    assert t.columns["a"].to_numpy()[-1] == 999
    assert t.columns["s"].to_numpy()[-1] == "z"
    db2.shutdown()


def test_in_memory_mode_discards(tmp_path):
    db = startup()
    db.create_table("x", {"v": np.arange(5, dtype=np.int64)})
    db.shutdown()
    db2 = startup()
    assert "x" not in db2.catalog


def test_database_locked(tmp_path):
    db = startup(str(tmp_path / "db3"))
    with pytest.raises(DatabaseError, match="locked"):
        startup(str(tmp_path / "db3"))
    db.shutdown()
    db3 = startup(str(tmp_path / "db3"))     # reopen after shutdown ok
    db3.shutdown()


def test_multiple_databases_per_process(tmp_path):
    """The paper's §5.1 limitation, fixed here: several engines at once."""
    a = startup(str(tmp_path / "a"))
    b = startup(str(tmp_path / "b"))
    c = startup()
    a.create_table("t", {"v": np.array([1], dtype=np.int64)})
    b.create_table("t", {"v": np.array([2], dtype=np.int64)})
    c.create_table("t", {"v": np.array([3], dtype=np.int64)})
    assert a.table("t").columns["v"].data[0] == 1
    assert b.table("t").columns["v"].data[0] == 2
    assert c.table("t").columns["v"].data[0] == 3
    a.shutdown(); b.shutdown(); c.shutdown()


def test_snapshot_isolation(db):
    db.create_table("t", {"v": np.array([1, 2], dtype=np.int64)})
    con = db.connect()
    con.begin()
    # concurrent (autocommit) append from another connection
    db.append("t", {"v": np.array([3], dtype=np.int64)})
    res = con.query("SELECT count(*) n FROM t")
    assert res.to_pydict()["n"][0] == 2          # snapshot: append invisible
    con.rollback()
    res = db.connect().query("SELECT count(*) n FROM t")
    assert res.to_pydict()["n"][0] == 3


def test_read_your_own_writes(db):
    db.create_table("t", {"v": np.array([1], dtype=np.int64)})
    con = db.connect()
    con.begin()
    con.append("t", {"v": np.array([2], dtype=np.int64)})
    assert con.query("SELECT count(*) n FROM t").to_pydict()["n"][0] == 2
    con.commit()
    assert db.table("t").num_rows == 2


def test_write_write_conflict(db):
    db.create_table("t", {"v": np.array([1], dtype=np.int64)})
    t1 = db.txn_manager.begin(db)
    t2 = db.txn_manager.begin(db)
    from repro.core.table import Table
    chunk = Table.from_dict("t", {"v": np.array([7], dtype=np.int64)})
    t1.append("t", chunk)
    t2.append("t", chunk)
    t1.commit()
    with pytest.raises(ConflictError):
        t2.commit()


def test_shutdown_frees_state(db):
    db.create_table("t", {"v": np.array([1], dtype=np.int64)})
    db.shutdown()
    with pytest.raises(DatabaseError):
        db.scan("t")


def test_checkpoint_truncates_wal(tmp_path):
    db = _mkdb(tmp_path / "db4")
    db.append("t", {"a": np.array([1], dtype=np.int64),
                    "s": np.asarray(["q"], dtype=object),
                    "d": np.array([0.1])})
    wal = tmp_path / "db4" / "wal" / "wal.jsonl"
    assert wal.exists() and wal.stat().st_size > 0
    db.checkpoint()
    assert not wal.exists() or wal.stat().st_size == 0
    db.shutdown()
    db2 = startup(str(tmp_path / "db4"))
    assert db2.table("t").num_rows == 101
    db2.shutdown()


def test_delete_installs_new_version(db):
    import numpy as np
    from repro.core import Col
    db.create_table("t", {"v": np.arange(100, dtype=np.int64)})
    n = db.delete("t", Col("v") >= 90)
    assert n == 10
    assert db.table("t").num_rows == 90
    assert db.table("t").version == 1


def test_delete_destroys_indexes(db):
    """Paper §3.1: indexes are destroyed on deletions."""
    import numpy as np
    from repro.core import Col
    db.create_table("t", {"v": np.arange(5000, dtype=np.float64)})
    db.index_manager.create_order_index("t", "v")
    db.index_manager.get_imprint("t", "v")
    db.delete("t", Col("v") < 10)
    assert db.index_manager.get_order_index("t", "v") is None
    # rebuilt lazily on next use, over the new version
    mask, _ = db.index_manager.imprint_mask("t", "v", 100, 200, False, False)
    assert mask.sum() == 101


def test_delete_persists(tmp_path):
    import numpy as np
    from repro.core import Col, startup
    db = startup(str(tmp_path / "d"))
    db.create_table("t", {"v": np.arange(10, dtype=np.int64)})
    db.delete("t", Col("v") > 4)
    db.shutdown()
    db2 = startup(str(tmp_path / "d"))
    assert db2.table("t").num_rows == 5
    db2.shutdown()


def test_delete_visible_only_after_snapshot(db):
    import numpy as np
    from repro.core import Col
    db.create_table("t", {"v": np.arange(10, dtype=np.int64)})
    con = db.connect()
    con.begin()
    db.delete("t", Col("v") >= 5)
    # the open snapshot still sees 10 rows
    assert con.query("SELECT count(*) n FROM t").to_pydict()["n"][0] == 10
    con.rollback()
    assert db.connect().query(
        "SELECT count(*) n FROM t").to_pydict()["n"][0] == 5


# ---------------------------------------------------------------------------
# durability satellites: torn-WAL recovery, version GC, context manager
# ---------------------------------------------------------------------------


def _crash(db):
    """Simulate a process crash: drop the in-process registry entry and the
    flock (which a real crash releases with its fds) without shutdown."""
    with __import__("repro.core.session", fromlist=["_open_lock"])._open_lock:
        from repro.core.session import _open_dirs
        _open_dirs.clear()
    db.storage.release_lock()


def _append_one(db, a, s, d):
    db.append("t", {"a": np.array([a], dtype=np.int64),
                    "s": np.asarray([s], dtype=object),
                    "d": np.array([d])})


def test_wal_torn_trailing_line_recovers(tmp_path):
    """A partial trailing wal.jsonl line (torn append) replays the good
    prefix, repairs the manifest, and keeps later appends reachable."""
    db = _mkdb(tmp_path / "db5")
    db.checkpoint()
    _append_one(db, 101, "p", 1.0)
    _append_one(db, 102, "q", 2.0)
    _crash(db)
    wal = tmp_path / "db5" / "wal" / "wal.jsonl"
    good = wal.read_bytes()
    wal.write_bytes(good + b'{"seq": 3, "table": "t", "fi')   # torn tail
    db2 = startup(str(tmp_path / "db5"))
    t = db2.table("t")
    assert t.num_rows == 102
    assert list(t.columns["a"].to_numpy()[-2:]) == [101, 102]
    # manifest was repaired: an append accepted now must survive the next
    # replay instead of hiding behind the torn line
    _append_one(db2, 103, "r", 3.0)
    _crash(db2)
    db3 = startup(str(tmp_path / "db5"))
    assert db3.table("t").num_rows == 103
    assert db3.table("t").columns["a"].to_numpy()[-1] == 103
    db3.shutdown()


def test_wal_missing_npz_recovers_to_prefix(tmp_path):
    """A manifest entry whose npz is gone stops replay at the last
    consistent state (the prefix) instead of reordering appends."""
    db = _mkdb(tmp_path / "db6")
    db.checkpoint()
    _append_one(db, 101, "p", 1.0)
    _append_one(db, 102, "q", 2.0)
    _crash(db)
    # the second append's data file vanishes (pre-fsync-era hole)
    import glob
    npzs = sorted(glob.glob(str(tmp_path / "db6" / "wal" / "*.npz")))
    os.unlink(npzs[-1])
    db2 = startup(str(tmp_path / "db6"))
    t = db2.table("t")
    assert t.num_rows == 101
    assert t.columns["a"].to_numpy()[-1] == 101
    db2.shutdown()


def test_wal_truncated_npz_recovers_to_prefix(tmp_path):
    """A *truncated* (zero-byte) npz — the pre-fsync durability hole —
    recovers like a missing one instead of failing the open."""
    db = _mkdb(tmp_path / "db6b")
    db.checkpoint()
    _append_one(db, 101, "p", 1.0)
    _append_one(db, 102, "q", 2.0)
    _crash(db)
    import glob
    npzs = sorted(glob.glob(str(tmp_path / "db6b" / "wal" / "*.npz")))
    with open(npzs[-1], "wb"):
        pass                                 # crash left zero bytes durable
    db2 = startup(str(tmp_path / "db6b"))
    t = db2.table("t")
    assert t.num_rows == 101
    assert t.columns["a"].to_numpy()[-1] == 101
    db2.shutdown()


def test_checkpoint_sweeps_stale_versions(tmp_path):
    """Superseded *.v<N>.bin / *.heap.json files are garbage-collected
    after a successful catalog write — data/ must not grow unboundedly."""
    db = _mkdb(tmp_path / "db7")
    data_dir = tmp_path / "db7" / "data"
    assert any(".v0." in f.name for f in data_dir.iterdir())
    for i in range(3):
        _append_one(db, 200 + i, "z", 0.5)
        db.checkpoint()                      # each bumps the table version
    names = [f.name for f in data_dir.iterdir()]
    assert not any(".v0." in n for n in names), names
    versions = {n.split(".v")[1].split(".")[0] for n in names if ".v" in n}
    assert len(versions) == 1                # only the live version remains
    db.shutdown()
    db2 = startup(str(tmp_path / "db7"))     # sweep never broke the catalog
    assert db2.table("t").num_rows == 103
    assert db2.table("t").columns["s"].to_numpy()[-1] == "z"
    db2.shutdown()


def test_atomic_write_leaves_no_temp_files(tmp_path):
    from repro.core.storage import _atomic_write
    target = tmp_path / "d" / "f.bin"
    _atomic_write(str(target), lambda f: f.write(b"payload"))
    assert target.read_bytes() == b"payload"
    _atomic_write(str(target), lambda f: f.write(b"v2"))
    assert target.read_bytes() == b"v2"
    assert [p.name for p in (tmp_path / "d").iterdir()] == ["f.bin"]


def test_database_context_manager(tmp_path):
    with startup(str(tmp_path / "db8")) as db:
        db.create_table("t", {"v": np.arange(4, dtype=np.int64)})
    with pytest.raises(DatabaseError):
        db.scan("t")                          # shutdown ran on exit
    with startup(str(tmp_path / "db8")) as db2:   # lock was released
        assert db2.table("t").num_rows == 4


def test_context_manager_releases_lock_on_error(tmp_path):
    with pytest.raises(RuntimeError, match="boom"):
        with startup(str(tmp_path / "db9")) as db:
            db.create_table("t", {"v": np.arange(2, dtype=np.int64)})
            raise RuntimeError("boom")
    with startup(str(tmp_path / "db9")) as db2:
        assert db2.table("t").num_rows == 2


def test_failed_startup_releases_directory_lock(tmp_path, monkeypatch):
    """If Database.__init__ dies after acquire_lock (here: spill
    reclamation raises), the flock must be released — otherwise the
    directory is locked forever by a database that never existed."""
    from repro.core.storage import Storage

    path = tmp_path / "dblock"
    _mkdb(path).shutdown()                     # create a valid directory

    def boom(self):
        raise OSError("disk error during reclaim")

    monkeypatch.setattr(Storage, "reclaim_spill", boom)
    with pytest.raises(OSError, match="disk error"):
        startup(str(path))
    monkeypatch.undo()

    db = startup(str(path))                    # leaked flock would raise
    assert db.table("t").num_rows == 100
    db.shutdown()


def test_failed_pid_note_releases_flock(tmp_path, monkeypatch):
    """acquire_lock itself must not leak the locked fd when writing the
    informational pid note fails."""
    from repro.core.storage import Storage

    path = tmp_path / "dbpid"
    _mkdb(path).shutdown()

    real_write = os.write

    def bad_write(fd, data):
        if data == str(os.getpid()).encode():
            raise OSError("write failed")
        return real_write(fd, data)

    st = Storage(str(path))
    monkeypatch.setattr(os, "write", bad_write)
    with pytest.raises(OSError, match="write failed"):
        st.acquire_lock()
    monkeypatch.undo()
    assert not st._locked

    db = startup(str(path))                    # fd leak would hold the flock
    db.shutdown()
