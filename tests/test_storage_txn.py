"""Persistence (memmap columns, WAL, recovery) + optimistic concurrency."""

import json
import os

import numpy as np
import pytest

from repro.core import ConflictError, DatabaseError, startup
from repro.core.session import Database


def _mkdb(path):
    db = startup(str(path))
    db.create_table("t", {"a": np.arange(100, dtype=np.int64),
                          "s": np.asarray(["x", "y"] * 50, dtype=object),
                          "d": np.arange(100) * 1.5})
    return db


def test_persist_and_reload(tmp_path):
    db = _mkdb(tmp_path / "db1")
    db.shutdown()
    db2 = startup(str(tmp_path / "db1"))
    t = db2.table("t")
    assert t.num_rows == 100
    assert list(t.columns["s"].to_numpy()[:2]) == ["x", "y"]
    # memmap-backed (the paper's mmap storage model)
    assert isinstance(t.columns["a"].data, np.memmap)
    db2.shutdown()


def test_wal_replay_after_crash(tmp_path):
    db = _mkdb(tmp_path / "db2")
    db.checkpoint()
    # bulk append goes to the WAL; simulate crash: NO shutdown/checkpoint
    db.append("t", {"a": np.array([999], dtype=np.int64),
                    "s": np.asarray(["z"], dtype=object),
                    "d": np.array([9.9])})
    with __import__("repro.core.session", fromlist=["_open_lock"])._open_lock:
        from repro.core.session import _open_dirs
        _open_dirs.clear()                      # drop the lock, not the data
    db.storage.release_lock()                   # a crash closes the flock fd
    db2 = startup(str(tmp_path / "db2"))
    t = db2.table("t")
    assert t.num_rows == 101
    assert t.columns["a"].to_numpy()[-1] == 999
    assert t.columns["s"].to_numpy()[-1] == "z"
    db2.shutdown()


def test_in_memory_mode_discards(tmp_path):
    db = startup()
    db.create_table("x", {"v": np.arange(5, dtype=np.int64)})
    db.shutdown()
    db2 = startup()
    assert "x" not in db2.catalog


def test_database_locked(tmp_path):
    db = startup(str(tmp_path / "db3"))
    with pytest.raises(DatabaseError, match="locked"):
        startup(str(tmp_path / "db3"))
    db.shutdown()
    db3 = startup(str(tmp_path / "db3"))     # reopen after shutdown ok
    db3.shutdown()


def test_multiple_databases_per_process(tmp_path):
    """The paper's §5.1 limitation, fixed here: several engines at once."""
    a = startup(str(tmp_path / "a"))
    b = startup(str(tmp_path / "b"))
    c = startup()
    a.create_table("t", {"v": np.array([1], dtype=np.int64)})
    b.create_table("t", {"v": np.array([2], dtype=np.int64)})
    c.create_table("t", {"v": np.array([3], dtype=np.int64)})
    assert a.table("t").columns["v"].data[0] == 1
    assert b.table("t").columns["v"].data[0] == 2
    assert c.table("t").columns["v"].data[0] == 3
    a.shutdown(); b.shutdown(); c.shutdown()


def test_snapshot_isolation(db):
    db.create_table("t", {"v": np.array([1, 2], dtype=np.int64)})
    con = db.connect()
    con.begin()
    # concurrent (autocommit) append from another connection
    db.append("t", {"v": np.array([3], dtype=np.int64)})
    res = con.query("SELECT count(*) n FROM t")
    assert res.to_pydict()["n"][0] == 2          # snapshot: append invisible
    con.rollback()
    res = db.connect().query("SELECT count(*) n FROM t")
    assert res.to_pydict()["n"][0] == 3


def test_read_your_own_writes(db):
    db.create_table("t", {"v": np.array([1], dtype=np.int64)})
    con = db.connect()
    con.begin()
    con.append("t", {"v": np.array([2], dtype=np.int64)})
    assert con.query("SELECT count(*) n FROM t").to_pydict()["n"][0] == 2
    con.commit()
    assert db.table("t").num_rows == 2


def test_write_write_conflict(db):
    db.create_table("t", {"v": np.array([1], dtype=np.int64)})
    t1 = db.txn_manager.begin(db)
    t2 = db.txn_manager.begin(db)
    from repro.core.table import Table
    chunk = Table.from_dict("t", {"v": np.array([7], dtype=np.int64)})
    t1.append("t", chunk)
    t2.append("t", chunk)
    t1.commit()
    with pytest.raises(ConflictError):
        t2.commit()


def test_shutdown_frees_state(db):
    db.create_table("t", {"v": np.array([1], dtype=np.int64)})
    db.shutdown()
    with pytest.raises(DatabaseError):
        db.scan("t")


def test_checkpoint_truncates_wal(tmp_path):
    db = _mkdb(tmp_path / "db4")
    db.append("t", {"a": np.array([1], dtype=np.int64),
                    "s": np.asarray(["q"], dtype=object),
                    "d": np.array([0.1])})
    wal = tmp_path / "db4" / "wal" / "wal.jsonl"
    assert wal.exists() and wal.stat().st_size > 0
    db.checkpoint()
    assert not wal.exists() or wal.stat().st_size == 0
    db.shutdown()
    db2 = startup(str(tmp_path / "db4"))
    assert db2.table("t").num_rows == 101
    db2.shutdown()


def test_delete_installs_new_version(db):
    import numpy as np
    from repro.core import Col
    db.create_table("t", {"v": np.arange(100, dtype=np.int64)})
    n = db.delete("t", Col("v") >= 90)
    assert n == 10
    assert db.table("t").num_rows == 90
    assert db.table("t").version == 1


def test_delete_destroys_indexes(db):
    """Paper §3.1: indexes are destroyed on deletions."""
    import numpy as np
    from repro.core import Col
    db.create_table("t", {"v": np.arange(5000, dtype=np.float64)})
    db.index_manager.create_order_index("t", "v")
    db.index_manager.get_imprint("t", "v")
    db.delete("t", Col("v") < 10)
    assert db.index_manager.get_order_index("t", "v") is None
    # rebuilt lazily on next use, over the new version
    mask, _ = db.index_manager.imprint_mask("t", "v", 100, 200, False, False)
    assert mask.sum() == 101


def test_delete_persists(tmp_path):
    import numpy as np
    from repro.core import Col, startup
    db = startup(str(tmp_path / "d"))
    db.create_table("t", {"v": np.arange(10, dtype=np.int64)})
    db.delete("t", Col("v") > 4)
    db.shutdown()
    db2 = startup(str(tmp_path / "d"))
    assert db2.table("t").num_rows == 5
    db2.shutdown()


def test_delete_visible_only_after_snapshot(db):
    import numpy as np
    from repro.core import Col
    db.create_table("t", {"v": np.arange(10, dtype=np.int64)})
    con = db.connect()
    con.begin()
    db.delete("t", Col("v") >= 5)
    # the open snapshot still sees 10 rows
    assert con.query("SELECT count(*) n FROM t").to_pydict()["n"][0] == 10
    con.rollback()
    assert db.connect().query(
        "SELECT count(*) n FROM t").to_pydict()["n"][0] == 5
