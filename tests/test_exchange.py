"""Zero-copy + lazy conversion (paper §3.3)."""

import numpy as np
import pytest

from repro.core import startup
from repro.core.column import Column
from repro.core.exchange import (LazyFrame, copy_for_write, export_table,
                                 is_zero_copy_eligible, import_arrays,
                                 to_device, zero_copy_view)
from repro.core.types import DBType


def test_zero_copy_shares_buffer():
    c = Column.from_values(np.arange(1000, dtype=np.int64), DBType.INT64)
    v = zero_copy_view(c)
    assert np.shares_memory(v, c.data)           # no bytes moved


def test_zero_copy_is_read_only():
    """The mprotect write-trap, numpy edition."""
    c = Column.from_values(np.arange(10, dtype=np.int64), DBType.INT64)
    v = zero_copy_view(c)
    with pytest.raises(ValueError):
        v[0] = 99


def test_copy_for_write_is_private():
    c = Column.from_values(np.arange(10, dtype=np.int64), DBType.INT64)
    w = copy_for_write(c)
    w[0] = 99
    assert c.data[0] == 0                        # engine data intact


def test_eligibility_rules():
    num = Column.from_values(np.arange(4, dtype=np.float64), DBType.FLOAT64)
    s = Column.from_values(["a", "b"], DBType.VARCHAR)
    dec = Column.from_values([1.5], DBType.DECIMAL, scale=2)
    assert is_zero_copy_eligible(num)
    assert not is_zero_copy_eligible(s)
    assert not is_zero_copy_eligible(dec)


def test_lazy_frame_converts_only_touched(db, rng):
    db.create_table("t", {
        "a": rng.integers(0, 10, 100).astype(np.int64),
        "b": rng.uniform(0, 1, 100),
        "s": np.asarray(["x", "y"], dtype=object)[rng.integers(0, 2, 100)],
        "d": np.round(rng.uniform(0, 9, 100), 2),
    })
    res = db.scan("t").select("a", "b", "s", "d").execute()
    lf = export_table(res, lazy=True)
    assert isinstance(lf, LazyFrame)
    _ = lf["s"]                       # touch one conversion-needing column
    _ = lf["a"]                       # and one zero-copy column
    assert lf.conversions == 1
    assert lf.zero_copies == 1
    assert lf.touched() == ["s", "a"]


def test_lazy_frame_caches(db):
    db.create_table("t", {"a": np.arange(10, dtype=np.int64)})
    lf = export_table(db.scan("t").execute())
    v1 = lf["a"]
    v2 = lf["a"]
    assert v1 is v2


def test_to_device_roundtrip():
    import jax.numpy as jnp
    c = Column.from_values(np.arange(16, dtype=np.float64), DBType.FLOAT64)
    d = to_device(c)
    assert isinstance(d, __import__("jax").Array)
    np.testing.assert_array_equal(np.asarray(d), c.data)


def test_import_arrays_adopts_numeric(rng):
    a = rng.uniform(0, 1, 100)
    t = import_arrays("x", {"a": a})
    assert np.shares_memory(np.asarray(t.columns["a"].data), a)


def test_result_fetch_low_and_high(db):
    db.create_table("t", {"a": np.arange(3, dtype=np.int64),
                          "s": np.asarray(["p", None, "q"], dtype=object)})
    res = db.connect().query("SELECT * FROM t")
    assert res.nrows == 3 and res.ncols == 2
    raw = res.fetch_raw(0)
    assert raw.dtype == np.int64 and not raw.flags.writeable
    vals, meta = res.fetch(1)
    assert list(vals) == ["p", None, "q"]
    assert meta.dbtype == DBType.VARCHAR
