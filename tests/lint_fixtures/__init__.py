# Known-bad snippets the golden tests feed to the invariant linter.
# Nothing here is imported at runtime; each bad line carries a "# BAD"
# marker the tests compare flagged line numbers against.
