"""The reconstructed pre-PR-6 TOCTOU: ``would_exceed()`` gating ``pin()``
outside any lock.  Two threads both pass the budget check, both pin, and
jointly overshoot — the exact bug ``try_pin`` replaced."""


# transfers-ownership: the pinned reserve travels with the returned tuple
def prefetch_next(bm, groups, i, submit):
    nnb = sum(p.nbytes for p in groups[i + 1])
    if not bm.would_exceed(nnb):    # BAD
        pnb = bm.pin(nnb)
        box, done = submit(groups[i + 1])
        return pnb, box, done
    return None
