"""Seeded guarded-by violations: declared-guarded fields touched outside
their lock — one declared via an inline comment, one via the per-class
registry (PlanCache)."""

import threading


class Gate:
    def __init__(self):
        self._cond = threading.Condition()
        self._reserved = 0          # guarded-by: _cond

    def reserve(self, n):
        with self._cond:
            self._reserved += n     # fine: under the declared lock

    def reserved(self):
        return self._reserved       # BAD


class PlanCache:
    def __init__(self):
        self._lock = threading.Lock()
        self._entries = {}

    def size(self):
        return len(self._entries)   # BAD
