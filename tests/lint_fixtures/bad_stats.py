"""Stats-discipline violations: a raw increment on a shared stats object
(unlocked read-modify-write) and a module-level mutable cache mutated at
runtime with no associated module lock."""

_RESULT_CACHE: dict = {}


def remember(key, value):
    _RESULT_CACHE[key] = value       # BAD


def count_hit(bufman):
    bufman.stats.prefetch_hits += 1  # BAD
