"""Jitted collective steps dispatched outside _DEVICE_DISPATCH_LOCK —
the PR 6 XLA rendezvous deadlock class.  ``step.lower(...)`` (AOT
inspection) would be fine; direct handle calls are not."""


def run_batches(spec, meta, mesh, batches):
    init_fn, step = _cached_batch_step(spec, meta, mesh, 128)  # noqa: F821
    carry = init_fn()               # BAD
    for arrs in batches:
        carry = step(carry, *arrs)  # BAD
    return carry
