"""Exception-path resource leaks: the release exists but is not reachable
when the body raises (straight-line release, no finally/except/with)."""


def load_group(bm, group):
    nb = sum(p.nbytes for p in group)
    pnb = bm.pin(nb)                # BAD
    arrs = [p.load() for p in group]
    bm.unpin(pnb)                   # straight-line: skipped if load raises
    return arrs


def open_database(storage):
    storage.acquire_lock()          # BAD
    catalog = storage.load_catalog()
    storage.release_lock()          # never runs if load_catalog raises
    return catalog
