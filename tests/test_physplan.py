"""Unified physical planner (core/physplan.py).

Contracts under test:

* **SQL–builder parity**: every TPC-H query with both entry points runs
  through SQL and the builder API across the {unlimited, 1 MiB, 64 KiB}
  host-budget matrix and must be *bit-identical* with identical tier
  annotations — one planner, many frontends (paper §3).
* **SQL hits the device tier** (the ROADMAP regression): normalization
  elides the SQL front-end's rename projection, so a SQL TPC-H Q1 routes
  device-resident/streamed exactly like the builder plan — asserted with a
  monkeypatch fence that makes any host fallback fail loudly.
* **Normalization** unit behaviour: identity-projection elision,
  rename-push into aggregates (only when column order is preserved),
  filter-conjunct canonicalization.
* **Smarter admission**: ``choose_device_tier`` biases borderline resident
  placement by the device cache's hit history.
* **Budgeted result materialization**: over-budget final tables stream to
  memmapped columns (``result_spills``), bit-identical, no leaked files.
* **Golden physical plans** for TPC-H Q1/Q3 under a forced 4-CPU-device
  topology (the ``physplan`` CI job), so tier annotations are pinned.
"""

import os

import numpy as np
import pytest

from repro.core import Col, startup
from repro.core.physplan import (TIER_DEVICE_RESIDENT, TIER_DEVICE_STREAMED,
                                 TIER_IN_MEMORY, TIER_SPILL,
                                 choose_device_tier, find_scan_agg_core,
                                 match_scan_agg, normalize, plan_physical)
from repro.data import tpch
from repro.data.tpch_queries import ALL_QUERIES, SQL_QUERIES

SF = 0.002
BUDGET_MATRIX = (None, 1 << 20, 64 << 10)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "golden")


@pytest.fixture(scope="module")
def dbs():
    out = {}
    for budget in BUDGET_MATRIX:
        db = startup(memory_budget=budget)
        tpch.load_into(db, sf=SF, seed=3)
        out[budget] = db
    return out


def _assert_bits(a: dict, b: dict, ctx: str):
    assert set(a) == set(b), ctx
    for c in a:
        av, bv = np.asarray(a[c]), np.asarray(b[c])
        if av.dtype == object or bv.dtype == object:
            assert list(map(str, av)) == list(map(str, bv)), (ctx, c)
        else:
            np.testing.assert_array_equal(av, bv, err_msg=f"{ctx} col={c}")


# ---------------------------------------------------------------------------
# differential SQL-vs-builder parity across the budget matrix
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("qname", list(SQL_QUERIES))
@pytest.mark.parametrize("budget", BUDGET_MATRIX)
def test_sql_builder_parity_budget_matrix(dbs, qname, budget):
    """Both entry points produce bit-identical results and identical tier
    annotations in every cell of the budget matrix."""
    db = dbs[budget]
    sql_plan = db.sql(SQL_QUERIES[qname]).plan
    builder_plan = ALL_QUERIES[qname](db).plan
    sql_res = db.sql(SQL_QUERIES[qname]).execute().to_pydict()
    b_res = ALL_QUERIES[qname](db).execute().to_pydict()
    _assert_bits(sql_res, b_res, f"{qname} budget={budget}")
    sql_phys = plan_physical(sql_plan, db)
    b_phys = plan_physical(builder_plan, db)
    assert sql_phys.tier_summary() == b_phys.tier_summary(), \
        (qname, budget, sql_phys.render(), b_phys.render())


def test_q1_q6_plans_fully_converge(dbs):
    """Q1/Q6 SQL and builder plans are *identical* after normalization
    (not just tier-equal): the rename projection folds away entirely."""
    db = dbs[None]
    for qname in ("q1", "q6"):
        sql_phys = plan_physical(db.sql(SQL_QUERIES[qname]).plan, db)
        b_phys = plan_physical(ALL_QUERIES[qname](db).plan, db)
        assert sql_phys.render() == b_phys.render(), qname


# ---------------------------------------------------------------------------
# SQL plans hit the device tier (ROADMAP regression)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def devdb():
    db = startup(device_budget=64 << 20)
    tpch.load_into(db, sf=SF, seed=3)
    return db


def test_sql_q1_routes_device_like_builder(devdb, monkeypatch):
    """SQL TPC-H Q1 routes device-resident/streamed identically to the
    builder plan.  The monkeypatch fence makes the ParallelExecutor's host
    program unreachable, so any silent fallback fails the test instead of
    hiding the routing regression."""
    from repro.core.parallel import ParallelExecutor

    def _fence(self, prog):
        raise AssertionError("host fallback — scan-agg core missed the "
                             "device tier")

    monkeypatch.setattr(ParallelExecutor, "run_program", _fence)
    b = ALL_QUERIES["q1"](devdb).execute(distributed=True).to_pydict()
    b_stats = devdb.last_stats
    assert b_stats.device_tier in ("resident", "streamed")
    b_plan = b_stats.plan_repr
    s = devdb.sql(SQL_QUERIES["q1"]).execute(distributed=True).to_pydict()
    s_stats = devdb.last_stats
    assert s_stats.device_tier == b_stats.device_tier
    assert s_stats.plan_repr == b_plan, "entry points must lower identically"
    # the SQL run reuses the builder run's cached device blocks: the
    # acceptance bar for "one planner, many frontends"
    assert s_stats.device_cache_hits > 0
    assert s_stats.device_bytes_h2d == 0
    _assert_bits(b, s, "q1 device parity")


def test_sql_q6_global_agg_routes_device(devdb):
    """Q6 (zero group keys, Project(Agg(Filter(Scan))) from SQL) also
    lowers to the device tier through normalization."""
    devdb.sql(SQL_QUERIES["q6"]).execute(distributed=True)
    assert devdb.last_stats.device_tier in ("resident", "streamed")


def test_suffix_runs_order_by_on_host(devdb):
    """ORDER BY above the scan-agg core no longer knocks the plan off the
    device tier: the core runs on devices, the suffix sorts the (tiny)
    assembled aggregate on host."""
    q = ALL_QUERIES["q1"](devdb)          # ends in .order_by(...)
    phys = plan_physical(q.plan, devdb, distributed=True)
    assert phys.agg_tier in (TIER_DEVICE_RESIDENT, TIER_DEVICE_STREAMED)
    assert phys.suffix_plan is not None
    out = q.execute(distributed=True).to_pydict()
    assert devdb.last_stats.device_tier in ("resident", "streamed")
    host = q.execute().to_pydict()        # host reference, same order
    rf = list(map(str, out["l_returnflag"]))
    assert rf == sorted(rf)
    np.testing.assert_allclose(
        np.asarray(out["sum_qty"], float),
        np.asarray(host["sum_qty"], float), rtol=1e-9)


# ---------------------------------------------------------------------------
# normalization units
# ---------------------------------------------------------------------------


def _mkdb(n=100):
    db = startup()
    db.create_table("t", {
        "g": (np.arange(n) % 4).astype(np.int64),
        "h": (np.arange(n) % 3).astype(np.int64),
        "x": np.linspace(0.0, 1.0, n),
    })
    return db


def test_normalize_elides_identity_projection():
    db = _mkdb()
    q = db.scan("t").select("g", "h", "x")
    from repro.core.relalg import ProjectNode, ScanNode
    norm = normalize(q.plan, db.catalog)
    assert isinstance(norm, ScanNode)
    # a column-dropping projection is NOT identity: it must survive
    norm2 = normalize(db.scan("t").select("g").plan, db.catalog)
    assert isinstance(norm2, ProjectNode)


def test_normalize_pushes_renames_into_aggregate():
    db = _mkdb()
    sql_plan = db.sql(
        "SELECT g, sum(x) AS total, count(*) AS n FROM t GROUP BY g").plan
    from repro.core.relalg import AggregateNode
    norm = normalize(sql_plan, db.catalog)
    assert isinstance(norm, AggregateNode)
    assert [a.name for a in norm.aggs] == ["total", "n"]


def test_normalize_keeps_reordering_projection():
    """SELECT order that permutes keys and aggregates is observable result
    column order — the projection must survive normalization."""
    db = _mkdb()
    sql_plan = db.sql(
        "SELECT sum(x) AS total, g FROM t GROUP BY g").plan
    from repro.core.relalg import ProjectNode
    norm = normalize(sql_plan, db.catalog)
    assert isinstance(norm, ProjectNode)
    res = db.sql("SELECT sum(x) AS total, g FROM t GROUP BY g").execute()
    assert res.schema.names == ("total", "g")


def test_normalize_canonicalizes_filter_conjuncts():
    db = _mkdb()
    a = db.scan("t").filter((Col("x") > 0.1) & (Col("g") < 3)).plan
    b = db.scan("t").filter(Col("g") < 3).filter(Col("x") > 0.1).plan
    na, nb = normalize(a, db.catalog), normalize(b, db.catalog)
    assert repr(na.predicate) == repr(nb.predicate)


def test_matcher_sees_through_suffix():
    """find_scan_agg_core locates the aggregate under order/limit/project
    chains and builds a suffix plan over the '#agg' result scan."""
    db = _mkdb(n=8192)
    q = (db.scan("t").filter(Col("x") > 0.5).group_by("g")
         .agg(s=("sum", "x")).order_by(("s", True)).limit(2))
    core, suffix = find_scan_agg_core(
        normalize(q.plan, db.catalog), db.catalog)
    assert core is not None and suffix is not None
    assert match_scan_agg(core, db.catalog) is not None
    from repro.core.relalg import LimitNode, OrderByNode
    assert isinstance(suffix, LimitNode)
    assert isinstance(suffix.child, OrderByNode)


# ---------------------------------------------------------------------------
# smarter admission: hit-history-biased residency
# ---------------------------------------------------------------------------


def test_choose_device_tier_hit_history_promotes_borderline():
    budget = 1 << 20
    batch = 64 << 10                       # streamable: 2*batch <= budget
    borderline = int(0.8 * budget)         # fits, but would crowd the cache
    small = int(0.2 * budget)
    # borderline + no history: stream (blocks still populate the cache)
    assert choose_device_tier(borderline, batch, budget,
                              hit_history=0) == "streamed"
    # borderline + repeat-access evidence: flip to resident
    assert choose_device_tier(borderline, batch, budget,
                              hit_history=1) == "resident"
    # small tables are resident immediately — history not required
    assert choose_device_tier(small, batch, budget,
                              hit_history=0) == "resident"
    # over-budget stays streamed no matter the history
    assert choose_device_tier(2 * budget, batch, budget,
                              hit_history=99) == "streamed"
    # unbudgeted placement is unchanged
    assert choose_device_tier(borderline, batch, None,
                              hit_history=0) == "resident"


def test_borderline_table_flips_streamed_to_resident():
    """End-to-end: the first query on a borderline table streams (no
    repeat-access evidence yet); streamed-mode blocks still populate the
    cache, so a repeat query observes hits and the table is promoted to
    resident."""
    n = 16384
    # table ≈ 272 KiB resident: fits the 400 KiB budget but takes > half
    db = startup(device_budget=400 << 10, device_batch_rows=4096)
    db.create_table("t", {"g": (np.arange(n) % 5).astype(np.int64),
                          "x": np.ones(n)})
    q = db.scan("t").group_by("g").agg(s=("sum", "x"))
    r1 = q.execute(distributed=True).to_pydict()
    assert db.last_stats.device_tier == "streamed", \
        "cold borderline table must stream, not monopolize the cache"
    assert db.device_manager.hit_history("t") == 0
    r2 = q.execute(distributed=True).to_pydict()   # hits accrue here
    assert db.last_stats.device_cache_hits > 0
    assert db.device_manager.hit_history("t") > 0
    r3 = q.execute(distributed=True).to_pydict()
    assert db.last_stats.device_tier == "resident", \
        "repeat queries on a borderline table must be promoted"
    for other in (r2, r3):
        _assert_bits(r1, other, "borderline promote")


def test_drop_table_forgets_admission_history():
    """DROP TABLE clears the hit history (a future table reusing the name
    must earn residency from scratch); appends keep it (repeat-access
    evidence is about the workload, not one table version)."""
    from repro.core.device_cache import DeviceBufferManager
    m = DeviceBufferManager(budget=None)
    m.put(("t", "c", 0, 0), np.zeros(64))
    m.get(("t", "c", 0, 0))
    assert m.hit_history("t") == 1
    m.invalidate_table("t")                  # append path: history kept
    assert m.hit_history("t") == 1
    m.invalidate_table("t", drop_history=True)   # DROP TABLE
    assert m.hit_history("t") == 0


def test_demoted_core_renders_host_annotation():
    """A device attempt that fails at runtime re-renders honestly: the
    core shows the host tier (no '(fused)' children, host byte model) and
    the stats do NOT claim device execution."""
    from repro.core.parallel import ParallelExecutor
    n = 8192
    db = startup(device_budget=64 << 20)
    db.create_table("t", {"g": (np.arange(n) % 5).astype(np.int64),
                          "x": np.ones(n)})
    # the extra LimitNode keeps the ORDER BY off the device (only a sort
    # DIRECTLY above the core fuses), so the host suffix path still runs
    q = (db.scan("t").group_by("g").agg(s=("sum", "x"))
         .order_by("g").limit(3))
    ref = q.execute().to_pydict()
    orig = ParallelExecutor._run_suffix
    try:
        def boom(self, sp, t):
            raise RuntimeError("suffix gap")
        ParallelExecutor._run_suffix = boom
        out = q.execute(distributed=True).to_pydict()
    finally:
        ParallelExecutor._run_suffix = orig
    st = db.last_stats
    assert st.device_tier == "", "host recompute must not claim the device"
    assert "(fused)" not in st.plan_repr
    assert "scan-agg core kept on host (runtime fallback)" in st.plan_repr
    _assert_bits(ref, out, "demoted")


def test_device_manager_hit_history_accounting():
    from repro.core.device_cache import DeviceBufferManager
    m = DeviceBufferManager(budget=None)
    m.put(("t", "c", 0, 0), np.zeros(64))
    m.put(("#carry", "p", 0, 0), np.zeros(64))
    assert m.hit_history("t") == 0
    m.get(("t", "c", 0, 0))
    m.get(("t", "c", 0, 0))
    m.get(("#carry", "p", 0, 0))
    assert m.hit_history("t") == 2
    assert m.hit_history("#carry") == 0    # intermediates never count
    m.cleanup()
    assert m.hit_history("t") == 0


# ---------------------------------------------------------------------------
# budgeted result materialization
# ---------------------------------------------------------------------------


def test_result_spills_to_memmap_bit_identical():
    n = 30_000
    data = {"k": np.arange(n, dtype=np.int64),
            "s": np.asarray([f"name-{i % 257}" for i in range(n)],
                            dtype=object),
            "x": np.linspace(-1.0, 1.0, n)}
    base = startup()
    db = startup(memory_budget=64 << 10)
    base.create_table("t", dict(data))
    db.create_table("t", dict(data))
    q = lambda d: (d.scan("t").filter(Col("x") > -0.5)
                   .project(k=Col("k"), s=Col("s"), y=Col("x") * 2.0))
    ref = q(base).execute().to_pydict()
    assert base.last_stats.result_spills == 0
    out = q(db).execute().to_pydict()
    assert db.last_stats.result_spills == 1
    assert db.buffer_manager.stats.result_spills == 1
    assert db.buffer_manager.active_files == 0, \
        "memmapped result files must be unlinked immediately"
    _assert_bits(ref, out, "result spill")


def test_result_spill_columns_are_memmapped():
    n = 30_000
    db = startup(memory_budget=32 << 10)
    db.create_table("t", {"x": np.arange(n, dtype=np.int64)})
    t = db.scan("t").project(y=Col("x") + 1).execute()
    assert isinstance(t.columns["y"].data, np.memmap)
    np.testing.assert_array_equal(np.asarray(t.columns["y"].data[:5]),
                                  np.arange(1, 6))


def test_small_results_stay_in_ram():
    db = startup(memory_budget=1 << 20)
    db.create_table("t", {"x": np.arange(100, dtype=np.int64)})
    t = db.scan("t").agg(s=("sum", "x")).execute()
    assert not isinstance(t.columns["s"].data, np.memmap)
    assert db.last_stats.result_spills == 0


# ---------------------------------------------------------------------------
# EXPLAIN observability
# ---------------------------------------------------------------------------


def test_explain_physical_shows_tiers():
    db = _mkdb(n=1000)
    txt = (db.scan("t").group_by("g").agg(s=("sum", "x"))
           .explain(physical=True))
    assert "physical plan" in txt
    assert TIER_IN_MEMORY in txt
    small = startup(memory_budget=1 << 10)
    small.create_table("t", {"k": np.arange(4096, dtype=np.int64),
                             "x": np.ones(4096)})
    txt2 = (small.scan("t").group_by("k").agg(s=("sum", "x"))
            .explain(physical=True))
    assert TIER_SPILL in txt2
    assert "memory_budget=1024" in txt2


def test_exec_stats_carry_plan_repr():
    db = _mkdb(n=500)
    db.scan("t").group_by("g").agg(s=("sum", "x")).execute()
    assert "physical plan" in db.last_stats.plan_repr
    assert "Aggregate" in db.last_stats.plan_repr


# ---------------------------------------------------------------------------
# golden physical plans (forced 4 CPU devices — the `physplan` CI job)
# ---------------------------------------------------------------------------


def _golden_db():
    db = startup(memory_budget=256 << 10, device_budget=64 << 20,
                 device_batch_rows=4096)
    tpch.load_into(db, sf=SF, seed=3)
    return db


@pytest.mark.parametrize("qname", ["q1", "q3"])
def test_golden_physical_plan(qname):
    import jax
    if jax.device_count() != 4:
        pytest.skip("golden plans are pinned to a forced 4-device topology "
                    "(CI: XLA_FLAGS=--xla_force_host_platform_device_count=4)")
    db = _golden_db()
    got = ALL_QUERIES[qname](db).explain(physical=True, distributed=True)
    path = os.path.join(GOLDEN_DIR, f"physplan_{qname}.txt")
    if os.environ.get("PHYSPLAN_REGOLD"):
        with open(path, "w") as f:
            f.write(got + "\n")
    with open(path) as f:
        want = f.read().rstrip("\n")
    assert got == want, f"golden physical plan drifted for {qname}:\n{got}"
