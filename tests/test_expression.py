"""Column-at-a-time expression evaluation incl. SQL null semantics."""

import numpy as np
import pytest

from repro.core.column import StringHeap
from repro.core.expression import (BinOp, Case, Cast, Col, DateLit,
                                   EvalContext, Func, InList, IsNull, Like,
                                   Lit, Not)
from repro.core.types import DBType, NULL_SENTINEL


def ctx(**cols):
    arrays, meta = {}, {}
    for name, spec in cols.items():
        if isinstance(spec, tuple):
            arr, t = spec[0], spec[1]
            heap = spec[2] if len(spec) > 2 else None
        else:
            arr, t, heap = np.asarray(spec), DBType.FLOAT64, None
        arrays[name] = np.asarray(arr)
        meta[name] = (t, heap, 0)
    return EvalContext(arrays, meta, xp=np)


def test_arithmetic():
    c = ctx(a=[1.0, 2.0], b=[10.0, 20.0])
    r = (Col("a") + Col("b") * 2).eval(c)
    np.testing.assert_allclose(r.values, [21.0, 42.0])


def test_division_by_zero_is_null():
    c = ctx(a=[1.0, 2.0], b=[0.0, 2.0])
    r = (Col("a") / Col("b")).eval(c)
    assert r.null.tolist() == [True, False]


def test_comparison_null_is_false():
    v = np.array([1, NULL_SENTINEL[DBType.INT64], 3], dtype=np.int64)
    c = ctx(a=(v, DBType.INT64))
    r = (Col("a") > 0).eval(c)
    assert r.values.tolist() == [1, 0, 1]
    assert r.null.tolist() == [False, True, False]


def test_three_valued_and_or():
    v = np.array([1, NULL_SENTINEL[DBType.INT64], 0], dtype=np.int64)
    c = ctx(a=(v, DBType.INT64), b=(np.array([1, 1, 1], np.int64),
                                    DBType.INT64))
    r = ((Col("a") > 0) & (Col("b") > 0)).eval(c)
    assert r.values.tolist() == [1, 0, 0]
    r = ((Col("a") > 0) | (Col("b") > 0)).eval(c)
    assert r.values.tolist() == [1, 1, 1]


def test_null_propagation_in_arith():
    v = np.array([1.0, np.nan])
    c = ctx(a=v)
    r = (Col("a") + 1).eval(c)
    assert r.null.tolist() == [False, True]


def test_isnull():
    c = ctx(a=[1.0, np.nan])
    assert IsNull(Col("a")).eval(c).values.tolist() == [0, 1]
    assert IsNull(Col("a"), negate=True).eval(c).values.tolist() == [1, 0]


def test_varchar_compare_on_codes():
    heap, codes = StringHeap.encode(["b", "a", "c", None])
    c = ctx(s=(codes, DBType.VARCHAR, heap))
    eq = (Col("s") == "b").eval(c)
    assert eq.values.tolist() == [1, 0, 0, 0]
    lt = (Col("s") < "c").eval(c)
    assert lt.values.tolist() == [1, 1, 0, 0]
    ge = (Col("s") >= "b").eval(c)
    assert ge.values.tolist() == [1, 0, 1, 0]


def test_like_dictionary_fast_path():
    heap, codes = StringHeap.encode(
        ["PROMO BRUSHED", "ECONOMY PLATED", "PROMO TIN", None])
    c = ctx(s=(codes, DBType.VARCHAR, heap))
    r = Like(Col("s"), "PROMO%").eval(c)
    assert r.values.tolist() == [1, 0, 1, 0]
    r = Like(Col("s"), "%TIN").eval(c)
    assert r.values.tolist() == [0, 0, 1, 0]


def test_in_list():
    heap, codes = StringHeap.encode(["x", "y", "z"])
    c = ctx(s=(codes, DBType.VARCHAR, heap))
    r = InList(Col("s"), ["x", "z"]).eval(c)
    assert r.values.tolist() == [1, 0, 1]


def test_between_sugar():
    c = ctx(a=[1.0, 5.0, 10.0])
    r = Col("a").between(2, 7).eval(c)
    assert r.values.tolist() == [0, 1, 0]


def test_case_when():
    c = ctx(a=[1.0, -1.0])
    e = Case(((Col("a") > 0, Lit(10.0)),), Lit(20.0))
    np.testing.assert_allclose(e.eval(c).values, [10.0, 20.0])


def test_year_function():
    from repro.core.types import date_from_string
    d = date_from_string(["1994-02-03", "2001-12-31"]).astype(np.int32)
    c = ctx(d=(d, DBType.DATE))
    assert Func("year", Col("d")).eval(c).values.tolist() == [1994, 2001]


def test_year_function_jnp_matches_np():
    import jax.numpy as jnp
    from repro.core.types import date_from_string
    days = date_from_string(
        ["1970-01-01", "1992-03-01", "1999-12-31", "2020-02-29"]
    ).astype(np.int32)
    cn = ctx(d=(days, DBType.DATE))
    r_np = Func("year", Col("d")).eval(cn).values
    arrays = {"d": jnp.asarray(days)}
    meta = {"d": (DBType.DATE, None, 0)}
    cj = EvalContext(arrays, meta, xp=jnp)
    r_j = np.asarray(Func("year", Col("d")).eval(cj).values)
    assert r_np.tolist() == r_j.tolist()


def test_date_literal_compare():
    from repro.core.types import date_from_string
    d = date_from_string(["1994-01-01", "1995-06-01"]).astype(np.int32)
    c = ctx(d=(d, DBType.DATE))
    r = (Col("d") < DateLit("1995-01-01")).eval(c)
    assert r.values.tolist() == [1, 0]


def test_cast():
    c = ctx(a=[1.7, 2.2])
    r = Cast(Col("a"), DBType.INT64).eval(c)
    assert r.values.dtype == np.int64
