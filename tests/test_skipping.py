"""Imprint-driven data skipping (physplan.derive_skip_sets + every consumer).

Differential skip-harness contracts:

* **Bit-identity**: selective-filter variants of TPC-H Q1/Q6 over a
  shipdate-sorted lineitem-like table at selectivities {~0%, 1%, 50%,
  100%} x host budgets {unlimited, 1 MiB, 64 KiB} x skipping {on,
  forced-off} are *bit-identical* — skipping is a pure optimization.
* **Counters**: ``blocks_skipped > 0`` whenever the filter is selective
  (the table is sorted so zone maps actually prune), ``== 0`` at 100%
  selectivity and always on a ``data_skipping=False`` database.
* **Fences**: monkeypatch fences prove non-qualifying blocks are never
  uploaded (``DeviceBufferManager.get_or_put``), never row-materialized
  by the volcano baseline (``_eval_row``), and never reach predicate
  evaluation on the host path (``BinOp.eval``).
* **Staleness**: appends/DELETE/DROP invalidate imprints and any cached
  plan's skip-set (version-keyed, like tests/test_serving.py); a
  txn-snapshot query must not see the committed table's skip-set.
* **NULL soundness**: integer NULL sentinels never satisfy open bounds
  (the ``imprint_mask`` regression) — the hypothesis superset property
  lives in tests/test_property.py.
"""

import numpy as np
import pytest

from repro.core import Col, startup
from repro.core.expression import Lit
from repro.core.indexes import IMPRINT_BLOCK
from repro.core.physplan import derive_skip_sets, plan_physical
from repro.core.types import DBType

N_BLOCKS = 6
N = N_BLOCKS * IMPRINT_BLOCK
BUDGET_MATRIX = (None, 1 << 20, 64 << 10)
SELECTIVITIES = ("empty", "one_pct", "half", "all")


def _dataset():
    """Lineitem-like, SORTED by the filter column (tpch's l_shipdate is
    uniform within each order window, so an unsorted table would zone-map
    to all-candidates; the paper's skipping argument assumes clustering)."""
    rng = np.random.default_rng(5)
    ship = np.sort(rng.integers(8000, 9200, N)).astype(np.int32)
    flags = np.asarray(["A", "N", "R"], dtype=object)
    status = np.asarray(["F", "O"], dtype=object)
    return {
        "ship": ship,
        "qty": rng.integers(1, 51, N).astype(np.float64),
        "price": np.round(rng.uniform(900, 105000, N), 2),
        "disc": np.round(rng.uniform(0.0, 0.10, N), 2),
        "tax": np.round(rng.uniform(0.0, 0.08, N), 2),
        "flag": flags[rng.integers(0, 3, N)],
        "status": status[rng.integers(0, 2, N)],
    }


def _cutoffs(ship):
    return {
        "empty": int(ship.min()) - 1,        # ~0%: below every block
        "one_pct": int(np.quantile(ship, 0.01)),
        "half": int(np.quantile(ship, 0.50)),
        "all": int(ship.max()) + 1,          # 100%: nothing prunable
    }


_DATA = _dataset()
_CUT = _cutoffs(_DATA["ship"])


def _mkdb(**kw):
    db = startup(**kw)
    db.create_table("li", _DATA, types={"ship": DBType.DATE})
    return db


def _q1(db, cut):
    """TPC-H Q1 shape: selective shipdate filter + grouped aggregate."""
    return (db.scan("li").filter(Col("ship") <= Lit(cut))
            .group_by("flag", "status")
            .agg(sq=("sum", "qty"), sp=("sum", "price"),
                 ad=("avg", "disc"), n=("count", None))
            .order_by("flag", "status"))


def _q6(db, cut):
    """TPC-H Q6 shape: conjunctive range filter + scalar aggregate (the
    ship conjunct prunes; disc/qty are unsorted so their imprints
    intersect to all-candidates — the AND path is still exercised)."""
    return (db.scan("li")
            .filter((Col("ship") <= Lit(cut)) & (Col("disc") <= Lit(0.07))
                    & (Col("qty") < Lit(24.0)))
            .agg(rev=("sum", Col("price") * Col("disc")),
                 n=("count", None)))


QUERIES = {"q1": _q1, "q6": _q6}


def _assert_bits(a: dict, b: dict, ctx: str):
    assert set(a) == set(b), ctx
    for c in a:
        av, bv = np.asarray(a[c]), np.asarray(b[c])
        if av.dtype == object or bv.dtype == object:
            assert list(map(str, av)) == list(map(str, bv)), (ctx, c)
        else:
            np.testing.assert_array_equal(av, bv, err_msg=f"{ctx} col={c}")


# ---------------------------------------------------------------------------
# differential harness: host path across the budget matrix
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def hostdbs():
    out = {}
    for budget in BUDGET_MATRIX:
        for skipping in (True, False):
            out[budget, skipping] = _mkdb(memory_budget=budget,
                                          data_skipping=skipping)
    yield out
    for db in out.values():
        db.shutdown()


@pytest.mark.parametrize("sel", SELECTIVITIES)
@pytest.mark.parametrize("budget", BUDGET_MATRIX)
@pytest.mark.parametrize("qname", list(QUERIES))
def test_skip_harness_bit_identical(hostdbs, qname, budget, sel):
    """Skipping on vs forced-off is bit-identical in every matrix cell,
    and the skip counters fire exactly when the filter is selective."""
    cut = _CUT[sel]
    on, off = hostdbs[budget, True], hostdbs[budget, False]
    r_on = QUERIES[qname](on, cut).execute().to_pydict()
    r_off = QUERIES[qname](off, cut).execute().to_pydict()
    _assert_bits(r_on, r_off, f"{qname} sel={sel} budget={budget}")
    assert off.last_stats.blocks_skipped == 0
    if sel == "all":
        assert on.last_stats.blocks_skipped == 0
    else:
        assert on.last_stats.blocks_skipped > 0, (qname, sel, budget)
        assert on.last_stats.bytes_skipped_spill > 0


def test_skip_counts_track_selectivity(hostdbs):
    """More selective cutoffs skip at least as many blocks (sorted data);
    ~0% skips the whole table."""
    db = hostdbs[None, True]
    skipped = {}
    for sel in SELECTIVITIES:
        _q1(db, _CUT[sel]).execute()
        skipped[sel] = db.last_stats.blocks_skipped
    assert skipped["empty"] == N_BLOCKS
    assert skipped["empty"] >= skipped["one_pct"] >= skipped["half"] \
        >= skipped["all"] == 0


def test_explain_annotates_skip_sets(hostdbs):
    """Query.explain(physical=True) renders the planning-time skip note on
    the scan; forced-off plans carry no note."""
    on, off = hostdbs[None, True], hostdbs[None, False]
    txt = _q1(on, _CUT["one_pct"]).explain(physical=True)
    assert "(skip: " in txt and "/6 blocks)" in txt
    assert "(skip: " not in _q1(off, _CUT["one_pct"]).explain(physical=True)
    # the derived bitmap matches what EXPLAIN printed
    phys = plan_physical(_q1(on, _CUT["empty"]).plan, on)
    assert any(ss.n_skipped == N_BLOCKS and ss.n_blocks == N_BLOCKS
               for ss in phys.skip_sets.values())


def test_host_fence_skipped_blocks_never_evaluated(monkeypatch):
    """At ~0% selectivity every block is pruned at the zone-map level: the
    filter predicate must never reach expression evaluation.  The fence
    poisons BinOp.eval, so any fallback to a real scan fails loudly."""
    from repro.core.expression import BinOp
    db = _mkdb()
    q = (db.scan("li").filter(Col("ship") <= Lit(_CUT["empty"]))
         .agg(n=("count", None), s=("sum", "price")))

    def _fence(self, ctx):
        raise AssertionError("predicate evaluated — imprint skip missed")

    monkeypatch.setattr(BinOp, "eval", _fence)
    got = q.execute().to_pydict()
    assert int(np.asarray(got["n"])[0]) == 0
    assert db.last_stats.blocks_skipped == N_BLOCKS
    db.shutdown()


def test_volcano_fence_skipped_rows_never_materialized(monkeypatch):
    """The row-store baseline consumes candidate_ranges(): with every
    block pruned it must not materialize (or per-row evaluate) a single
    tuple."""
    from repro.core import volcano as vol
    from repro.core.optimizer import optimize
    db = _mkdb()
    calls = []
    real = vol._eval_row
    monkeypatch.setattr(vol, "_eval_row",
                        lambda e, row: calls.append(1) or real(e, row))
    plan = optimize(_q1(db, _CUT["empty"]).plan, db.catalog)
    rows = vol.VolcanoExecutor(db).execute(plan)
    assert rows == []
    assert calls == []
    assert db.buffer_manager.stats.blocks_skipped == N_BLOCKS
    db.shutdown()


def test_volcano_matches_engine_with_skipping():
    """Volcano over candidate ranges == columnar engine, partial
    selectivity (the boundary block is a candidate but half-filtered)."""
    from repro.core.optimizer import optimize
    from repro.core.volcano import VolcanoExecutor
    db = _mkdb()
    q = _q1(db, _CUT["half"])
    eng = q.execute().to_pydict()
    rows = VolcanoExecutor(db).execute(optimize(q.plan, db.catalog))
    vol = {k: [r[k] for r in rows] for k in eng}
    for k in ("sq", "sp", "n"):
        np.testing.assert_allclose(np.asarray(eng[k], dtype=float),
                                   np.asarray(vol[k], dtype=float))
    db.shutdown()


# ---------------------------------------------------------------------------
# device tier: batches of non-qualifying blocks are never uploaded
# ---------------------------------------------------------------------------


def _mkdevdb(**kw):
    return _mkdb(device_budget=64 << 20, device_batch_rows=4096, **kw)


@pytest.mark.parametrize("sel", SELECTIVITIES)
def test_device_bit_identical(sel):
    """Cold device runs, skipping on vs forced-off: bit-identical, and the
    h2d counters account every batch exactly once (uploaded or skipped)."""
    on, off = _mkdevdb(), _mkdevdb(data_skipping=False)
    try:
        q = lambda d: (d.scan("li").filter(Col("ship") <= Lit(_CUT[sel]))
                       .group_by("flag", "status")
                       .agg(sq=("sum", "qty"), n=("count", None))
                       .order_by("flag", "status"))
        r_on = q(on).execute(distributed=True).to_pydict()
        r_off = q(off).execute(distributed=True).to_pydict()
        _assert_bits(r_on, r_off, f"device sel={sel}")
        s_on, s_off = on.last_stats, off.last_stats
        assert s_off.bytes_skipped_h2d == 0
        if sel == "all":
            assert s_on.bytes_skipped_h2d == 0
            assert s_on.blocks_skipped == 0
        else:
            assert s_on.bytes_skipped_h2d > 0, sel
            assert s_on.device_bytes_h2d < s_off.device_bytes_h2d
        if sel == "empty":
            assert s_on.blocks_skipped == N_BLOCKS
            assert s_on.device_bytes_h2d == 0
    finally:
        on.shutdown()
        off.shutdown()


def test_device_fence_skipped_batches_never_uploaded(monkeypatch):
    """Fence on the device block cache: with every block pruned, no
    (table, column, version, shard) key for the scanned table may ever
    reach get_or_put — uploads of skipped batches fail the test."""
    from repro.core.device_cache import DeviceBufferManager
    db = _mkdevdb()
    try:
        uploads = []
        real = DeviceBufferManager.get_or_put

        def spy(self, key, *a, **kw):
            if key[0] == "li":
                uploads.append(key)
            return real(self, key, *a, **kw)

        monkeypatch.setattr(DeviceBufferManager, "get_or_put", spy)
        got = (db.scan("li").filter(Col("ship") <= Lit(_CUT["empty"]))
               .group_by("flag", "status").agg(n=("count", None))
               .execute(distributed=True).to_pydict())
        assert list(got["n"]) == [] or all(v == 0 for v in got["n"])
        assert uploads == []
        assert db.last_stats.blocks_skipped == N_BLOCKS
    finally:
        db.shutdown()


def test_device_partial_skip_uploads_only_live_batches(monkeypatch):
    """1% selectivity with 4096-row batches: only the first batch
    qualifies; the fence pins the uploaded batch indices to the live set
    (shard component of the cache key carries the batch index)."""
    from repro.core.device_cache import DeviceBufferManager
    db = _mkdevdb()
    try:
        batches = set()
        real = DeviceBufferManager.get_or_put

        def spy(self, key, *a, **kw):
            if key[0] == "li":
                batches.add(key[3][2])
            return real(self, key, *a, **kw)

        monkeypatch.setattr(DeviceBufferManager, "get_or_put", spy)
        (db.scan("li").filter(Col("ship") <= Lit(_CUT["one_pct"]))
         .group_by("flag", "status").agg(n=("count", None))
         .execute(distributed=True))
        assert batches == {0}, batches
    finally:
        db.shutdown()


# ---------------------------------------------------------------------------
# staleness: version-keyed skip-sets under append / DELETE / DROP / txn
# ---------------------------------------------------------------------------


def _count(db, cut):
    return int(np.asarray(
        db.scan("li").filter(Col("ship") <= Lit(cut))
        .agg(n=("count", None)).execute().to_pydict()["n"])[0])


class TestStaleness:
    def test_append_invalidates_skip_sets(self):
        """Appended qualifying rows land in a tail block the old bitmap
        never covered: a stale skip-set would silently drop them."""
        db = _mkdb()
        cut = _CUT["one_pct"]
        before = _count(db, cut)
        assert db.last_stats.blocks_skipped > 0
        assert len(db.plan_cache) == 1
        extra = 64
        db.append("li", {
            "ship": np.full(extra, _CUT["empty"], dtype=np.int32),
            "qty": np.ones(extra), "price": np.ones(extra),
            "disc": np.zeros(extra), "tax": np.zeros(extra),
            "flag": ["A"] * extra, "status": ["F"] * extra,
        })
        # delta append: the stale entry ages out by LRU; the version-fenced
        # key (version, base_version, delta_epoch) makes it unreachable,
        # and the extended imprint covers the appended tail block
        assert _count(db, cut) == before + extra
        assert db.last_stats.plan_cache_hit is False
        db.shutdown()

    def test_plan_cache_key_differs_on_version_and_flag(self):
        """The cache key carries (table, version) AND the data_skipping
        flag: neither an append nor a flag flip can serve a stale
        skip-set even without explicit invalidation."""
        from repro.core.serving import PlanCache
        on, off = _mkdb(), _mkdb(data_skipping=False)
        try:
            q = _q1(on, _CUT["half"]).plan
            k_on = PlanCache.key(on, q, do_optimize=True, distributed=False)
            k_off = PlanCache.key(off, q, do_optimize=True,
                                  distributed=False)
            assert k_on != k_off
            assert k_on[-1] is True and k_off[-1] is False
            on.append("li", {k: v[:1] for k, v in _DATA.items()})
            k_on2 = PlanCache.key(on, q, do_optimize=True, distributed=False)
            assert k_on2 != k_on          # version component moved
        finally:
            on.shutdown()
            off.shutdown()

    def test_delete_invalidates_imprints(self):
        db = _mkdb()
        cut = _CUT["half"]
        before = _count(db, cut)
        db.delete("li", Col("ship") <= Lit(cut))
        assert _count(db, cut) == 0
        # and the inverse region is intact
        assert _count(db, _CUT["all"]) == N - before
        db.shutdown()

    def test_drop_and_recreate_no_stale_skip_set(self):
        db = _mkdb()
        _count(db, _CUT["empty"])
        db.drop_table("li")
        # recreate with shifted values: a stale bitmap would skip all
        shifted = dict(_DATA)
        shifted["ship"] = (_DATA["ship"] - 5000).astype(np.int32)
        db.create_table("li", shifted, types={"ship": DBType.DATE})
        assert _count(db, _CUT["all"]) == N
        db.shutdown()

    def test_txn_snapshot_does_not_see_committed_skip_set(self):
        """A transaction's snapshot database derives skip-sets from its
        OWN IndexManager over snapshot tables: rows committed after
        ``begin`` must stay invisible — a skip-set (or imprint) leaked
        from the parent would disagree with the snapshot's row count."""
        db = _mkdb()
        cut = _CUT["one_pct"]
        before = _count(db, cut)        # parent imprints + plan cache warm
        con = db.connect()
        con.begin()
        n0 = con.query(
            f"SELECT COUNT(*) AS n FROM li WHERE ship <= {cut}")
        db.append("li", {k: (v[:32] if k != "ship" else
                             np.full(32, cut - 1, dtype=np.int32))
                         for k, v in _DATA.items()})
        n1 = con.query(
            f"SELECT COUNT(*) AS n FROM li WHERE ship <= {cut}")
        con.rollback()
        assert int(np.asarray(n0.to_pydict()["n"])[0]) == before
        assert int(np.asarray(n1.to_pydict()["n"])[0]) == before
        assert _count(db, cut) == before + 32    # committed view sees them
        db.shutdown()


# ---------------------------------------------------------------------------
# NULL-sentinel soundness (the imprint_mask regression)
# ---------------------------------------------------------------------------


def test_int_null_sentinel_never_satisfies_open_bounds():
    """INT64 NULLs are sentinel-coded as INT64_MIN, which numerically
    satisfies any ``col < x``: the imprint mask must still reject them
    (SQL comparisons are NULL-rejecting).  Regression for the fix in
    indexes.imprint_mask."""
    db = startup()
    vals = [None, 5, None, 10, 1, None] * 400     # > AUTO_ORDER_MIN_ROWS
    db.create_table("t", {"x": vals})
    im = db.index_manager.imprint_mask("t", "x", float("-inf"), 7.0,
                                       False, True)
    assert im is not None
    mask, _ = im
    exact = np.asarray([v is not None and v < 7 for v in vals])
    np.testing.assert_array_equal(mask, exact)
    got = (db.scan("t").filter(Col("x") < Lit(7))
           .agg(n=("count", None)).execute().to_pydict())
    assert int(np.asarray(got["n"])[0]) == int(exact.sum())
    db.shutdown()


def test_skip_set_revalidation_guards_row_count():
    """Defense in depth: a SkipSet whose version or row count disagrees
    with the live table is discarded by the device scan (valid_for)."""
    db = _mkdevdb()
    try:
        phys = plan_physical(
            _q1(db, _CUT["one_pct"]).plan, db, distributed=True)
        sets = list(phys.skip_sets.values())
        assert sets and all(
            ss.valid_for(db.catalog.table("li")) for ss in sets)
        db.append("li", {k: v[:1] for k, v in _DATA.items()})
        assert all(not ss.valid_for(db.catalog.table("li")) for ss in sets)
    finally:
        db.shutdown()


def test_derive_skip_sets_respects_flag_and_string_filters():
    """No skip-set for a VARCHAR filter (imprints are numeric-only) and
    none at all when data_skipping is off."""
    on, off = _mkdb(), _mkdb(data_skipping=False)
    try:
        from repro.core.optimizer import optimize
        num = optimize(_q1(on, _CUT["half"]).plan, on.catalog)
        assert derive_skip_sets(num, on)
        assert derive_skip_sets(num, off) == {}
        s = optimize(on.scan("li").filter(Col("flag") == Lit("A"))
                     .agg(n=("count", None)).plan, on.catalog)
        assert derive_skip_sets(s, on) == {}
    finally:
        on.shutdown()
        off.shutdown()
