"""Training infra: checkpoint durability/retention/elasticity, fault
machinery, optimizer properties, end-to-end resume."""

import json
import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.train.checkpoint import (latest_step, restore_checkpoint,
                                    save_checkpoint)
from repro.train.fault import (Heartbeat, RestartPolicy, StragglerDetector,
                               elastic_mesh_shape)
from repro.train.optimizer import (AdamWConfig, adamw_update, compress_int8,
                                   compress_tree, decompress_int8,
                                   init_opt_state, schedule)


def _params(rng):
    return {"a": {"w": jnp.asarray(rng.normal(size=(8, 8)), jnp.float32)},
            "b": jnp.asarray(rng.normal(size=(4,)), jnp.float32)}


# ---- checkpointing --------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path, rng):
    p = _params(rng)
    o = init_opt_state(p)
    save_checkpoint(str(tmp_path), 5, p, o, extra={"cursor": 42})
    p2, o2, extra, step = restore_checkpoint(str(tmp_path))
    assert step == 5 and extra["cursor"] == 42
    np.testing.assert_array_equal(np.asarray(p["a"]["w"]), p2["a"]["w"])
    np.testing.assert_array_equal(np.asarray(o["m"]["b"]), o2["m"]["b"])


def test_checkpoint_retention(tmp_path, rng):
    p = _params(rng)
    o = init_opt_state(p)
    for s in range(6):
        save_checkpoint(str(tmp_path), s, p, o, retain=3)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 3
    assert latest_step(str(tmp_path)) == 5


def test_checkpoint_async(tmp_path, rng):
    p = _params(rng)
    o = init_opt_state(p)
    t = save_checkpoint(str(tmp_path), 1, p, o, async_write=True)
    t.join(timeout=30)
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_reshard_on_restore(tmp_path, rng):
    """Elastic restore: leaves are full-shape; re-placement with new
    shardings succeeds on a different (here: trivial) mesh."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    p = _params(rng)
    o = init_opt_state(p)
    save_checkpoint(str(tmp_path), 1, p, o)
    from repro.launch.mesh import make_mesh_compat
    mesh = make_mesh_compat((1,), ("data",))
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), {
        "params": p, "opt_state": o})
    p2, o2, _, _ = restore_checkpoint(str(tmp_path), shardings=sh)
    assert p2["a"]["w"].sharding.mesh.shape["data"] == 1


# ---- fault tolerance --------------------------------------------------------


def test_heartbeat_detects_dead(tmp_path):
    a = Heartbeat(str(tmp_path), "host-a", dead_after_s=10)
    b = Heartbeat(str(tmp_path), "host-b", dead_after_s=10)
    a.beat(1, now=1000.0)
    b.beat(1, now=1000.0)
    assert a.dead_hosts(now=1005.0) == []
    b.beat(2, now=1020.0)
    assert a.dead_hosts(now=1025.0) == ["host-a"]


def test_straggler_detection_and_rebalance():
    s = StragglerDetector(window=8, straggler_factor=1.5)
    for _ in range(8):
        s.record("fast1", 1.0)
        s.record("fast2", 1.1)
        s.record("slow", 2.5)
    assert s.stragglers() == ["slow"]
    plan = s.rebalance_plan({"fast1": 4, "fast2": 4, "slow": 4})
    assert plan["slow"] == 3 and sum(plan.values()) == 12


def test_restart_policy_and_elastic_mesh():
    rp = RestartPolicy(max_restarts=2)
    assert rp.on_failure([], 64) == "continue"
    assert rp.on_failure(["h3"], 64) == "elastic_restart"
    assert rp.on_failure(["h4"], 63) == "elastic_restart"
    assert rp.on_failure(["h5"], 62) == "abort"
    assert elastic_mesh_shape(60, 4, 16) == (15, 16)


# ---- optimizer ---------------------------------------------------------------


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    lrs = [float(schedule(cfg, jnp.asarray(s))) for s in
           [0, 5, 10, 50, 100]]
    assert lrs[1] == pytest.approx(0.5e-3)
    assert lrs[2] == pytest.approx(1e-3)
    assert lrs[3] < lrs[2]
    assert lrs[4] == pytest.approx(1e-4, rel=1e-2)


def test_adamw_moves_towards_gradient(rng):
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.ones((4,), jnp.float32)}
    st = init_opt_state(p)
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=10,
                      weight_decay=0.0)
    p2, st2, m = adamw_update(cfg, p, g, st)
    assert (np.asarray(p2["w"]) < 1.0).all()
    assert int(st2["step"]) == 1


def test_grad_clip(rng):
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 1e6, jnp.float32)}
    cfg = AdamWConfig(grad_clip=1.0)
    _, _, m = adamw_update(cfg, p, g, init_opt_state(p))
    assert float(m["grad_norm"]) > 1.0     # reported pre-clip


def test_int8_compression_error_feedback(rng):
    x = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    q, s = compress_int8(x)
    err1 = x - decompress_int8(q, s)
    assert float(jnp.abs(err1).max()) <= float(s) * 0.5 + 1e-6
    grads = {"w": x}
    errors = {"w": jnp.zeros_like(x)}
    q1, s1, e1 = compress_tree(grads, errors)
    # feeding the error back keeps the residual bounded across steps
    q2, s2, e2 = compress_tree(grads, e1)
    assert float(jnp.abs(e2["w"]).mean()) \
        <= 2 * float(jnp.abs(e1["w"]).mean()) + 1e-6


# ---- end-to-end resume --------------------------------------------------------


def test_train_driver_resume(tmp_path):
    from repro.launch.train import build_parser, run
    args = build_parser().parse_args([
        "--steps", "6", "--batch", "2", "--seq-len", "32", "--d-model",
        "64", "--layers", "1", "--ckpt-dir", str(tmp_path / "ck"),
        "--ckpt-every", "2", "--run-dir", str(tmp_path / "run"),
        "--db-dir", str(tmp_path / "db"), "--log-every", "0"])
    r1 = run(args)
    assert r1["steps"] == 6
    # "crash" after step 6; restart trains steps 6..10 only
    args2 = build_parser().parse_args([
        "--steps", "10", "--batch", "2", "--seq-len", "32", "--d-model",
        "64", "--layers", "1", "--ckpt-dir", str(tmp_path / "ck"),
        "--run-dir", str(tmp_path / "run2"),
        "--db-dir", str(tmp_path / "db2"), "--log-every", "0"])
    # reuse the same checkpoint dir -> resumes at 6
    r2 = run(args2)
    assert r2["steps"] == 4
