"""Hypothesis property tests on engine invariants."""

import os

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the 'hypothesis' test extra")
from hypothesis import assume, given, settings, strategies as st  # noqa: E402
from hypothesis.database import DirectoryBasedExampleDatabase  # noqa: E402

from repro.core import Col, startup
from repro.core.column import StringHeap
from repro.core.types import DBType

# Found counterexamples persist in-repo: CI (and every later run) replays
# them first, so a shrunk failure from any machine becomes a regression test.
_EXAMPLES_DIR = os.path.join(os.path.dirname(__file__),
                             "hypothesis_examples")
settings.register_profile(
    "ci", max_examples=40, deadline=None,
    database=DirectoryBasedExampleDatabase(_EXAMPLES_DIR))
settings.load_profile("ci")


ints = st.lists(st.integers(-1000, 1000), min_size=1, max_size=300)
floats = st.lists(
    st.floats(min_value=-1e6, max_value=1e6, allow_nan=False),
    min_size=1, max_size=300)
strings = st.lists(st.one_of(st.none(), st.text(
    alphabet="abcdefg", min_size=0, max_size=6)),
    min_size=1, max_size=200)


def mkdb(**cols):
    db = startup()
    db.create_table("t", {k: np.asarray(v) if not isinstance(v, list)
                          or not any(x is None for x in v)
                          else v for k, v in cols.items()})
    return db


@given(floats, st.floats(min_value=-1e6, max_value=1e6, allow_nan=False))
def test_filter_partitions_table(xs, threshold):
    """|σ(p)| + |σ(¬p)| == |T| for null-free data."""
    db = mkdb(x=np.asarray(xs))
    lo = db.scan("t").filter(Col("x") < threshold) \
        .agg(n=("count", None)).execute().to_pydict()["n"][0]
    hi = db.scan("t").filter(~(Col("x") < threshold)) \
        .agg(n=("count", None)).execute().to_pydict()["n"][0]
    assert lo + hi == len(xs)


@given(ints)
def test_groupby_sums_to_total(ks):
    db = mkdb(k=np.asarray(ks, dtype=np.int64),
              v=np.ones(len(ks)))
    got = db.scan("t").group_by("k").agg(s=("sum", "v")).execute()
    total = np.asarray(got.to_pydict()["s"], dtype=float).sum()
    assert total == len(ks)


@given(ints)
def test_sort_is_permutation(ks):
    db = mkdb(k=np.asarray(ks, dtype=np.int64))
    got = db.scan("t").order_by("k").execute().to_pydict()["k"]
    assert sorted(ks) == [int(v) for v in got]


@given(strings)
def test_heap_roundtrip(ss):
    heap, codes = StringHeap.encode(ss)
    decoded = heap.decode(codes)
    for orig, dec, code in zip(ss, decoded, codes):
        if orig is None:
            assert code == 0
        else:
            assert dec == orig


@given(strings)
def test_heap_codes_order_preserving(ss):
    vals = [s for s in ss if s is not None]
    assume(len(vals) >= 2)
    heap, codes = StringHeap.encode(vals)
    order_by_code = np.argsort(codes, kind="stable")
    sorted_vals = [vals[i] for i in order_by_code]
    assert sorted_vals == sorted(vals)


@given(ints, ints)
def test_join_cardinality_matches_bruteforce(a, b):
    db = startup()
    db.create_table("l", {"k": np.asarray(a, dtype=np.int64)})
    db.create_table("r", {"k": np.asarray(b, dtype=np.int64)})
    got = db.scan("l").join(db.scan("r"), on="k") \
        .agg(n=("count", None)).execute().to_pydict()["n"][0]
    brute = sum((np.asarray(b) == x).sum() for x in a)
    assert got == brute


@given(floats, st.floats(-1e6, 1e6, allow_nan=False),
       st.floats(-1e6, 1e6, allow_nan=False))
def test_imprint_never_misses(xs, lo, hi):
    """Zone-map pruning is complete: pruned mask == exact predicate."""
    assume(lo <= hi)
    xs = (xs * 40)[:8000]           # large enough to build imprints
    db = mkdb(x=np.asarray(xs))
    im = db.index_manager.imprint_mask("t", "x", lo, hi, False, False)
    if im is None:
        return
    mask, _ = im
    exact = (np.asarray(xs) >= lo) & (np.asarray(xs) <= hi)
    np.testing.assert_array_equal(mask, exact)


# ---------------------------------------------------------------------------
# imprint candidate_blocks: superset soundness over adversarial data shapes
# ---------------------------------------------------------------------------

_IMPRINT_ROWS = 3 * 2048        # 3 full IMPRINT_BLOCKs (>= AUTO_ORDER_MIN)


@st.composite
def imprint_case(draw):
    """(values, lo, hi, lo_strict, hi_strict) over data shapes that stress
    the zone maps: clustered (sorted — the paying case), uniform,
    constant (degenerate histogram range), and NaN-sprinkled; bounds are
    either arbitrary or snapped near drawn data values (bin-edge
    collisions)."""
    shape = draw(st.sampled_from(["clustered", "uniform", "constant",
                                  "nans"]))
    base = draw(st.lists(st.floats(-1e4, 1e4, allow_nan=False),
                         min_size=2, max_size=50))
    reps = -(-_IMPRINT_ROWS // len(base))
    vals = np.asarray((base * reps)[:_IMPRINT_ROWS], dtype=np.float64)
    if shape == "clustered":
        vals = np.sort(vals)
    elif shape == "constant":
        vals = np.full(_IMPRINT_ROWS, base[0])
    elif shape == "nans":
        for i in draw(st.lists(st.integers(0, _IMPRINT_ROWS - 1),
                               max_size=30)):
            vals[i] = np.nan
    bound = st.one_of(st.floats(-1e4, 1e4, allow_nan=False),
                      st.sampled_from(base))
    lo, hi = sorted((draw(bound), draw(bound)))
    return vals, lo, hi, draw(st.booleans()), draw(st.booleans())


@given(imprint_case())
def test_candidate_blocks_is_superset(case):
    """Soundness: every block holding a qualifying (non-NULL) row is a
    candidate — skipping may over-approximate, never under-approximate."""
    from repro.core.indexes import IMPRINT_BLOCK
    vals, lo, hi, lo_s, hi_s = case
    db = mkdb(x=vals)
    info = db.index_manager.candidate_info("t", "x", lo, hi, lo_s, hi_s)
    assert info is not None
    cand, block, n_rows = info
    assert block == IMPRINT_BLOCK and n_rows == len(vals)
    ok = (vals > lo) if lo_s else (vals >= lo)
    ok &= (vals < hi) if hi_s else (vals <= hi)
    ok &= ~np.isnan(vals)
    for b in range(len(cand)):
        if ok[b * block:(b + 1) * block].any():
            assert cand[b], f"block {b} holds qualifying rows but was skipped"


@given(imprint_case())
def test_candidate_blocks_matches_bin_edges(case):
    """Bounds snapped exactly onto the imprint's own histogram bin edges
    (the clip/floor boundary) must stay sound too."""
    vals, _, _, lo_s, hi_s = case
    db = mkdb(x=vals)
    im = db.index_manager.get_imprint("t", "x")
    assert im is not None
    if not np.isfinite(im.lo) or not np.isfinite(im.hi) or im.hi <= im.lo:
        return
    edges = im.lo + np.arange(17) * (im.hi - im.lo) / 16
    for lo, hi in ((edges[3], edges[5]), (edges[0], edges[0]),
                   (edges[15], edges[16])):
        cand = im.candidate_blocks(lo, hi, lo_s, hi_s)
        ok = (vals > lo) if lo_s else (vals >= lo)
        ok &= (vals < hi) if hi_s else (vals <= hi)
        ok &= ~np.isnan(vals)
        for b in range(len(cand)):
            if ok[b * im.block:(b + 1) * im.block].any():
                assert cand[b], (lo, hi, b)


@given(st.lists(st.one_of(st.none(), st.integers(-1000, 1000)),
                min_size=4, max_size=60))
def test_candidate_blocks_int_nulls_sound(ks):
    """Integer columns code NULL as INT64_MIN: sentinel rows must neither
    force extra candidates via poisoned mins nor count as qualifying."""
    assume(any(k is not None for k in ks))
    reps = -(-_IMPRINT_ROWS // len(ks))
    col = (ks * reps)[:_IMPRINT_ROWS]
    db = mkdb(x=col)
    info = db.index_manager.candidate_info("t", "x", -500.0, 500.0,
                                           False, False)
    assert info is not None
    cand, block, _ = info
    ok = np.asarray([v is not None and -500 <= v <= 500 for v in col])
    for b in range(len(cand)):
        if ok[b * block:(b + 1) * block].any():
            assert cand[b]


@given(st.lists(st.sampled_from(["aa", "ab", "ba", "c", ""]),
                min_size=1, max_size=100),
       st.sampled_from(["a%", "%b", "%a%", "c", "_a", "%"]))
def test_like_matches_fnmatch(ss, pattern):
    import fnmatch
    db = mkdb(s=np.asarray(ss, dtype=object))
    got = db.scan("t").filter(Col("s").like(pattern)) \
        .agg(n=("count", None)).execute().to_pydict()["n"][0]
    pat = pattern.replace("%", "*").replace("_", "?")
    exp = sum(fnmatch.fnmatchcase(s, pat) for s in ss)
    assert got == exp


@given(ints)
def test_append_then_count(ks):
    db = mkdb(k=np.asarray(ks, dtype=np.int64))
    db.append("t", {"k": np.asarray(ks, dtype=np.int64)})
    n = db.scan("t").agg(n=("count", None)).execute().to_pydict()["n"][0]
    assert n == 2 * len(ks)


@given(st.lists(st.integers(0, 5), min_size=8, max_size=200),
       st.integers(2, 5))
def test_chunked_merge_invariant(ks, n_chunks):
    """Fig. 2: partial aggregation over any chunking merges identically."""
    from repro.core.optimizer import optimize
    from repro.core.parallel import ParallelExecutor, match_scan_agg
    db = mkdb(k=np.asarray(ks, dtype=np.int64), v=np.ones(len(ks)))
    q = db.scan("t").group_by("k").agg(s=("sum", "v"))
    spec = match_scan_agg(optimize(q.plan, db.catalog), db.catalog)
    assume(spec is not None)
    ex = ParallelExecutor(db)
    np.testing.assert_allclose(ex.run_chunked_host(spec, 1),
                               ex.run_chunked_host(spec, n_chunks))


@given(floats)
def test_median_between_min_max(xs):
    db = mkdb(x=np.asarray(xs))
    got = db.scan("t").agg(m=("median", "x"), lo=("min", "x"),
                           hi=("max", "x")).execute().to_pydict()
    assert got["lo"][0] <= got["m"][0] <= got["hi"][0]


# ---------------------------------------------------------------------------
# VARCHAR spilling across heaps: budgeted == in-memory, property-level
# ---------------------------------------------------------------------------

_skeys = st.lists(st.one_of(st.none(), st.text(alphabet="abcde", min_size=0,
                                               max_size=4)),
                  min_size=1, max_size=40)


@st.composite
def varchar_key_sides(draw):
    """Two (str|None) key columns whose value sets are disjoint,
    overlapping, or identical — each side loaded separately, so the two
    VARCHAR columns always carry distinct heap objects."""
    left = draw(_skeys)
    mode = draw(st.sampled_from(["disjoint", "overlap", "identical"]))
    if mode == "identical":
        right = list(left)
    elif mode == "disjoint":
        right = [None if s is None else s + "zz" for s in draw(_skeys)]
    else:
        shared = [s for s in left if s is not None]
        extra = draw(_skeys)
        picks = (draw(st.lists(st.sampled_from(shared), max_size=20))
                 if shared else [])
        right = extra + picks
    return left, right


def _tile(keys, rows):
    """Repeat a small drawn key list up to ``rows`` rows so the join/group
    state reliably exceeds the tiny budgets (the spill decision is
    cardinality-driven)."""
    reps = -(-rows // len(keys))
    return (keys * reps)[:rows]


def _mk_sides(left, right, budget):
    db = startup(memory_budget=budget)
    lk = _tile(left, 700)
    rk = _tile(right, 700)
    db.create_table("l", {"s": lk, "v": np.arange(len(lk), dtype=np.int64)})
    db.create_table("r", {"s": rk, "w": np.arange(len(rk), dtype=np.int64)})
    return db


# 16 KiB fits the (tiny) merged heap -> shared-dictionary strategy;
# 1 KiB cannot even hold the heaps -> decoded-string-bytes strategy.
_TINY_BUDGETS = [16 << 10, 1 << 10]


def _is_varchar(db) -> bool:
    return db.table("l").columns["s"].dbtype == DBType.VARCHAR


@given(varchar_key_sides())
def test_varchar_join_spill_equals_memory(sides):
    """Budgeted join on (str|None) keys with distinct heaps == in-memory
    join, for disjoint, overlapping and identical key sets, under both the
    merged-heap and decoded-bytes strategies."""
    left, right = sides
    base = _mk_sides(left, right, None)
    q = lambda d: (d.scan("l").join(d.scan("r"), on="s")
                   .agg(c=("count", None), sv=("sum", "v"),
                        sw=("sum", "w")).execute().to_pydict())
    want = q(base)
    for budget in _TINY_BUDGETS:
        db = _mk_sides(left, right, budget)
        got = q(db)
        for c in want:
            np.testing.assert_array_equal(want[c], got[c],
                                          err_msg=f"budget={budget} {c}")
        assert db.buffer_manager.stats.spilled_ops > 0
        if _is_varchar(db):    # all-NULL draws don't infer VARCHAR at all
            assert db.buffer_manager.stats.varchar_spills > 0
        assert db.buffer_manager.active_files == 0


@given(varchar_key_sides())
def test_varchar_groupby_spill_equals_memory(sides):
    """Budgeted group-by over a (str|None) key (composite with a
    high-cardinality tiebreaker, so the grouping state must spill) ==
    in-memory group-by, including the NULL group and output order."""
    left, _ = sides
    base = _mk_sides(left, left, None)
    q = lambda d: (d.scan("l").group_by("s", "v")
                   .agg(c=("count", None)).execute().to_pydict())
    want = q(base)
    for budget in _TINY_BUDGETS:
        db = _mk_sides(left, left, budget)
        got = q(db)
        assert [None if v is None else str(v) for v in want["s"]] \
            == [None if v is None else str(v) for v in got["s"]], budget
        np.testing.assert_array_equal(want["v"], got["v"],
                                      err_msg=str(budget))
        np.testing.assert_array_equal(want["c"], got["c"],
                                      err_msg=str(budget))
        assert db.buffer_manager.stats.spilled_ops > 0
        if _is_varchar(db):
            assert db.buffer_manager.stats.varchar_spills > 0
        assert db.buffer_manager.active_files == 0
