"""Chunked/distributed execution (Fig. 2) + the volcano baseline engine."""

import numpy as np
import pytest

from repro.core import Col, startup
from repro.core.optimizer import optimize
from repro.core.parallel import (ParallelExecutor, match_scan_agg)
from repro.core.volcano import VolcanoExecutor


@pytest.fixture
def pdb(rng):
    db = startup()
    n = 20_000
    db.create_table("t", {
        "k": np.asarray(["a", "b", "c"], dtype=object)[
            rng.integers(0, 3, n)],
        "g": rng.integers(0, 5, n).astype(np.int64),
        "x": rng.uniform(0, 100, n),
    })
    return db


def _q(db):
    return (db.scan("t").filter((Col("x") > 10) & (Col("x") < 90))
            .group_by("k").agg(s=("sum", "x"), n=("count", None),
                               mn=("min", "x"), mx=("max", "x"),
                               a=("avg", "x")))


def _norm(d):
    order = np.argsort([str(s) for s in d["k"]])
    return {k: np.asarray(v)[order] for k, v in d.items()}


def test_pattern_matcher(pdb):
    plan = optimize(_q(pdb).plan, pdb.catalog)
    spec = match_scan_agg(plan, pdb.catalog)
    assert spec is not None
    assert spec.table == "t" and spec.group_keys == ["k"]
    assert len(spec.conjuncts) == 2


def test_distributed_equals_sequential(pdb):
    seq = _norm(_q(pdb).execute().to_pydict())
    dist = _norm(_q(pdb).execute(distributed=True).to_pydict())
    for k in seq:
        a, b = seq[k], dist[k]
        if a.dtype == object and isinstance(a[0], str):
            assert list(map(str, a)) == list(map(str, b))
        else:
            np.testing.assert_allclose(a.astype(float), b.astype(float),
                                       rtol=1e-9)


def test_chunked_host_merge_equals_whole(pdb):
    """Per-chunk partials + merge == single-chunk run (Fig. 2 algebra)."""
    plan = optimize(_q(pdb).plan, pdb.catalog)
    spec = match_scan_agg(plan, pdb.catalog)
    ex = ParallelExecutor(pdb)
    one = ex.run_chunked_host(spec, 1)
    many = ex.run_chunked_host(spec, 7)
    np.testing.assert_allclose(one, many, rtol=1e-12)


def test_distributed_int_group_keys(pdb):
    q = pdb.scan("t").group_by("g").agg(s=("sum", "x"))
    seq = q.execute().to_pydict()
    dist = q.execute(distributed=True).to_pydict()
    np.testing.assert_allclose(np.sort(seq["s"]), np.sort(dist["s"]),
                               rtol=1e-9)


def test_distributed_fallback_for_joins(pdb, rng):
    pdb.create_table("d", {"g": np.arange(5, dtype=np.int64),
                           "w": rng.uniform(0, 1, 5)})
    q = pdb.scan("t").join(pdb.scan("d"), on="g").agg(s=("sum", "w"))
    a = q.execute().to_pydict()
    b = q.execute(distributed=True).to_pydict()     # falls back, same result
    np.testing.assert_allclose(a["s"], b["s"])


# ---- volcano baseline ------------------------------------------------------


def test_volcano_matches_columnar_agg(pdb):
    plan = optimize(_q(pdb).plan, pdb.catalog)
    rows = VolcanoExecutor(pdb).execute(plan)
    col = _norm(_q(pdb).execute().to_pydict())
    rows = sorted(rows, key=lambda r: r["k"])
    for i, r in enumerate(rows):
        assert r["k"] == col["k"][i]
        np.testing.assert_allclose(r["s"], col["s"][i], rtol=1e-9)
        assert r["n"] == col["n"][i]


def test_volcano_join_and_sort(pdb, rng):
    pdb.create_table("d", {"g": np.arange(5, dtype=np.int64),
                           "w": rng.uniform(0, 1, 5)})
    q = (pdb.scan("t").join(pdb.scan("d"), on="g")
         .group_by("g").agg(s=("sum", Col("x") * Col("w")))
         .order_by(("s", True)).limit(3))
    plan = optimize(q.plan, pdb.catalog)
    rows = VolcanoExecutor(pdb).execute(plan)
    col = q.execute().to_pydict()
    assert len(rows) == 3
    for i, r in enumerate(rows):
        np.testing.assert_allclose(r["s"], col["s"][i], rtol=1e-9)
