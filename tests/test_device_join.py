"""Device-tier joins and sorts (physplan join-agg matching + the
DistributedJoinAgg streams + device-resident assembly).

Differential budget-matrix contracts:

* **Bit-identity**: TPC-H Q3 across device budgets {unlimited, 64 MiB,
  4 MiB, 2 MiB} x skipping {on, forced-off} is *bit-identical* in every
  device cell — the budget only changes residency (resident vs streamed),
  never a result byte — and every cell matches the host join tier.
* **Peak accounting**: ``device_bytes_peak <= device_budget`` in every
  budgeted cell; the 2 MiB cell actually streams (``join-streamed``).
* **Fences**: monkeypatch fences prove the host hash join is never
  entered on the device path and the (n_groups, K) partial matrix is
  never finalized on host (assembly is device-resident).
* **Soundness gates**: duplicate build keys trip the on-device
  uniqueness witness and fall back to the (correct) host join; NULL
  probe keys never match.
* **Fused ORDER BY**: the device lexsort permutation equals the host
  suffix sort's (``device_sorted`` claims the fusion), for both the
  join tier and the scan-agg tier.
"""

import numpy as np
import pytest

from repro.core import Col, startup
from repro.core.expression import Lit
from repro.core.indexes import IMPRINT_BLOCK
from repro.core.types import DBType
from repro.data.tpch import generate
from repro.data.tpch_queries import q3

DEVICE_BUDGETS = (None, 64 << 20, 4 << 20, 2 << 20)
BATCH_ROWS = 8192          # small enough that the 2 MiB cell streams

_TPCH = generate(0.01, 7)
_Q3_TABLES = ("customer", "orders", "lineitem")


def _mkdb(**kw):
    db = startup(**kw)
    for name in _Q3_TABLES:
        cols, types, scales = _TPCH[name]
        db.create_table(name, cols, types=types, scales=scales)
    return db


def _rows(d: dict):
    """Row-major view of a to_pydict result, exact on every dtype."""
    cols = []
    for c in d.values():
        v = np.asarray(c)
        cols.append(list(map(str, v)) if v.dtype == object else list(v))
    return list(zip(*cols))


def _assert_matches(got: dict, want: dict, ctx: str, exact: bool):
    assert list(got) == list(want), ctx
    for c in got:
        gv, wv = np.asarray(got[c]), np.asarray(want[c])
        if gv.dtype == object or wv.dtype == object:
            assert list(map(str, gv)) == list(map(str, wv)), (ctx, c)
        elif exact:
            np.testing.assert_array_equal(gv, wv, err_msg=f"{ctx} col={c}")
        else:
            np.testing.assert_allclose(np.asarray(gv, float),
                                       np.asarray(wv, float),
                                       rtol=1e-9, err_msg=f"{ctx} col={c}")


# ---------------------------------------------------------------------------
# differential harness: Q3 across the device budget matrix
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def host_q3():
    db = _mkdb()
    try:
        yield q3(db).execute().to_pydict()
    finally:
        db.shutdown()


@pytest.fixture(scope="module")
def device_cells():
    """Q3 in every (device_budget, skipping) cell, one cold db per cell."""
    out = {}
    for budget in DEVICE_BUDGETS:
        for skipping in (True, False):
            db = _mkdb(device_budget=budget,
                       device_batch_rows=BATCH_ROWS,
                       data_skipping=skipping)
            try:
                res = q3(db).execute(distributed=True).to_pydict()
                s = db.last_stats
                out[budget, skipping] = (
                    res, s.device_tier, s.device_sorted,
                    s.device_bytes_peak)
            finally:
                db.shutdown()
    return out


def test_q3_matrix_runs_on_device(device_cells):
    """Budgeted cells run the device join; the tight 2 MiB budget must
    actually stream (resident state exceeds it), and the lifetime HBM
    peak stays under the budget in every budgeted cell."""
    for (budget, skipping), (_res, tier, _srt, peak) in device_cells.items():
        if budget is not None:
            assert tier.startswith("join-"), (budget, skipping, tier)
            assert peak <= budget, (budget, skipping, peak)
    assert device_cells[2 << 20, True][1] == "join-streamed"
    assert device_cells[64 << 20, True][1] == "join-resident"


def test_q3_matrix_bit_identical(device_cells):
    """The budget (and skipping) are pure optimizations: every device
    cell that ran the join tier returns byte-identical results."""
    ran = {k: v for k, v in device_cells.items()
           if v[1].startswith("join-")}
    assert len(ran) >= 6
    items = list(ran.items())
    ref_key, (ref, *_rest) = items[0]
    for key, (res, *_s) in items[1:]:
        _assert_matches(res, ref, f"{key} vs {ref_key}", exact=True)


def test_q3_matrix_matches_host(device_cells, host_q3):
    """Every device cell agrees with the host join tier (same rows, same
    order — the fused device sort reproduces the suffix sort)."""
    for key, (res, tier, sorted_, _peak) in device_cells.items():
        _assert_matches(res, host_q3, f"{key} tier={tier}", exact=False)
        if tier.startswith("join-"):
            assert sorted_, key     # Q3's ORDER BY ... LIMIT 10 fused


def test_q3_explain_annotates_device_join_and_sort():
    db = _mkdb(device_budget=64 << 20)
    try:
        txt = q3(db).explain(physical=True, distributed=True)
        assert ":: device-join" in txt
        assert ":: device-sort" in txt
        assert "mode=resident" in txt
    finally:
        db.shutdown()


# ---------------------------------------------------------------------------
# fences: the device path must never touch the host join or finalize
# ---------------------------------------------------------------------------


def test_fence_host_join_never_entered(monkeypatch, host_q3):
    """Poison both host join kernels: a device-tier Q3 that silently fell
    back to the host join fails loudly."""
    from repro.core import executor as ex
    from repro.core import spill

    def _fence(*a, **kw):
        raise AssertionError("host hash join entered on the device path")

    monkeypatch.setattr(ex, "_hash_join", _fence)
    monkeypatch.setattr(spill, "partitioned_hash_join", _fence)
    db = _mkdb(device_budget=64 << 20, device_batch_rows=BATCH_ROWS)
    try:
        res = q3(db).execute(distributed=True).to_pydict()
        assert db.last_stats.device_tier.startswith("join-")
        _assert_matches(res, host_q3, "host-join fence", exact=False)
    finally:
        db.shutdown()


def test_fence_partials_never_finalized_on_host(monkeypatch, host_q3):
    """Assembly is device-resident: the (n_groups, K) carry must be
    finalized/compacted by the jitted assembly step, never by the host
    ``finalize_partials``."""
    from repro.core import parallel as par

    def _fence(*a, **kw):
        raise AssertionError("partials reached host finalize_partials")

    monkeypatch.setattr(par, "finalize_partials", _fence)
    db = _mkdb(device_budget=64 << 20, device_batch_rows=BATCH_ROWS)
    try:
        res = q3(db).execute(distributed=True).to_pydict()
        assert db.last_stats.device_tier.startswith("join-")
        _assert_matches(res, host_q3, "host-finalize fence", exact=False)
    finally:
        db.shutdown()


# ---------------------------------------------------------------------------
# soundness gates: duplicate build keys, NULL probe keys
# ---------------------------------------------------------------------------


def _star(db, dim_rows, group=("fk", "grp")):
    """Small star schema: a fact table probing one dimension build,
    grouped at build-key granularity (the Q3 shape)."""
    rng = np.random.default_rng(11)
    n = 20_000
    db.create_table("dim", dim_rows)
    db.create_table("fact", {
        "fk": rng.integers(0, 180, n).astype(np.int64),
        "v": rng.standard_normal(n),
    })
    return (db.scan("fact")
            .join(db.scan("dim"), left_on="fk", right_on="k")
            .group_by(*group)
            .agg(s=("sum", Col("v")), n=("count", None))
            .order_by(*group))


def test_duplicate_build_keys_fall_back_to_host_join(host_q3):
    """The dupmax witness: a duplicated build key would double-count in
    the dense build matrix, so the device join must refuse at runtime
    and the host join must produce the (duplicated-row) truth."""
    dim = {
        "k": np.concatenate([np.arange(200),
                             np.asarray([7])]).astype(np.int64),
        "grp": np.concatenate([np.arange(200) % 5,
                               np.asarray([3])]).astype(np.int64),
    }
    dev = startup(device_budget=64 << 20, device_batch_rows=BATCH_ROWS)
    host = startup()
    try:
        qd, qh = _star(dev, dim), _star(host, dim)
        got = qd.execute(distributed=True).to_pydict()
        assert dev.last_stats.device_tier == ""      # witness fired
        _assert_matches(got, qh.execute().to_pydict(), "dup keys",
                        exact=False)
    finally:
        dev.shutdown()
        host.shutdown()


def test_null_probe_keys_never_match():
    """NULL fact keys are sentinel-coded; the probe mask must reject them
    (an inner join drops NULL keys) — differential vs the host join."""
    dim = {"k": np.arange(200).astype(np.int64),
           "grp": (np.arange(200) % 5).astype(np.int64)}
    dev = startup(device_budget=64 << 20, device_batch_rows=BATCH_ROWS)
    host = startup()
    try:
        qd, qh = _star(dev, dim), _star(host, dim)
        for db in (dev, host):
            db.delete("fact", Col("fk") < Lit(0))    # no-op, keeps shape
            db.append("fact", {"fk": [None] * 64,
                               "v": np.ones(64)})
        got = qd.execute(distributed=True).to_pydict()
        assert dev.last_stats.device_tier.startswith("join-")
        _assert_matches(got, qh.execute().to_pydict(), "null keys",
                        exact=False)
    finally:
        dev.shutdown()
        host.shutdown()


def test_payload_only_grouping_stays_on_host():
    """The device tier groups at build-key granularity: GROUP BY a
    dimension attribute alone (coarser — needs a re-merge) must NOT be
    claimed by the device join, and the host result is authoritative."""
    dim = {"k": np.arange(200).astype(np.int64),
           "grp": (np.arange(200) % 5).astype(np.int64)}
    dev = startup(device_budget=64 << 20, device_batch_rows=BATCH_ROWS)
    host = startup()
    try:
        qd = _star(dev, dim, group=("grp",))
        qh = _star(host, dim, group=("grp",))
        got = qd.execute(distributed=True).to_pydict()
        assert dev.last_stats.device_tier == ""
        want = qh.execute().to_pydict()
        assert len(np.asarray(got["grp"])) == 5
        _assert_matches(got, want, "payload-only grouping", exact=False)
    finally:
        dev.shutdown()
        host.shutdown()


# ---------------------------------------------------------------------------
# fused device sort on the scan-agg tier
# ---------------------------------------------------------------------------


def test_scan_agg_device_sort_matches_host():
    """ORDER BY over a grouped scan-agg fuses onto the device assembly
    (``device_sorted``) and reproduces the host suffix sort exactly —
    including DESC on an aggregate and a LIMIT."""
    rng = np.random.default_rng(3)
    n = 40_000
    data = {"g": (np.arange(n) % 97).astype(np.int64),
            "v": rng.standard_normal(n)}
    dev = startup(device_budget=64 << 20, device_batch_rows=BATCH_ROWS)
    host = startup()
    try:
        for db in (dev, host):
            db.create_table("t", data)
        q = lambda d: (d.scan("t").group_by("g")
                       .agg(s=("sum", Col("v")), n=("count", None))
                       .order_by(("s", True), "g", limit=20))
        got = q(dev).execute(distributed=True).to_pydict()
        s = dev.last_stats
        assert s.device_tier == "resident" and s.device_sorted
        want = q(host).execute().to_pydict()
        assert _rows({k: np.round(np.asarray(v, float), 6)
                      for k, v in got.items()}) \
            == _rows({k: np.round(np.asarray(v, float), 6)
                      for k, v in want.items()})
        _assert_matches(got, want, "scan-agg device sort", exact=False)
    finally:
        dev.shutdown()
        host.shutdown()


# ---------------------------------------------------------------------------
# intra-batch skipping: gathered boundary batches
# ---------------------------------------------------------------------------


def test_intra_batch_gather_reduces_h2d_bit_identically():
    """Block-clustered alternating data, one 32768-row batch: every other
    imprint block qualifies, so the batch is live but half its blocks are
    dead — the gathered trace uploads only candidate slots.  h2d bytes
    drop, ``bytes_skipped_h2d`` accounts the savings, and the result is
    bit-identical to the ungathered run (and the host)."""
    n = 16 * IMPRINT_BLOCK
    blk_vals = np.where(np.arange(16) % 2 == 0, 100, 900)
    rng = np.random.default_rng(5)
    data = {"ship": np.repeat(blk_vals, IMPRINT_BLOCK).astype(np.int32),
            "qty": rng.integers(1, 51, n).astype(np.float64),
            "flag": np.asarray(["A", "N", "R"],
                               dtype=object)[rng.integers(0, 3, n)]}

    def mk(**kw):
        db = startup(**kw)
        db.create_table("li", data, types={"ship": DBType.DATE})
        return db

    def q(db):
        return (db.scan("li").filter(Col("ship") <= Lit(500))
                .group_by("flag")
                .agg(total=("sum", Col("qty")), n=("count", None))
                .order_by("flag"))

    on = mk(device_budget=64 << 20, device_batch_rows=n)
    off = mk(device_budget=64 << 20, device_batch_rows=n,
             data_skipping=False)
    host = mk()
    try:
        r_on = q(on).execute(distributed=True).to_pydict()
        r_off = q(off).execute(distributed=True).to_pydict()
        s_on, s_off = on.last_stats, off.last_stats
        # one live batch, so ALL savings here are intra-batch gather
        assert s_on.bytes_skipped_h2d > 0
        assert s_on.device_bytes_h2d < s_off.device_bytes_h2d
        assert s_off.bytes_skipped_h2d == 0
        _assert_matches(r_on, r_off, "gather on/off", exact=True)
        _assert_matches(r_on, q(host).execute().to_pydict(), "vs host",
                        exact=False)
    finally:
        on.shutdown()
        off.shutdown()
        host.shutdown()
