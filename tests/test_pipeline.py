"""DB-backed training data pipeline: zero-copy feed, cursor semantics,
engine-side curation, exactly-once restart."""

import numpy as np
import pytest

from repro.core import startup
from repro.data.pipeline import TokenPipeline, curate, tokenize_corpus


@pytest.fixture
def corpus_db():
    db = startup()
    tokenize_corpus(db, 10_000, vocab=512, seed=1)
    return db


def test_corpus_in_store(corpus_db):
    t = corpus_db.table("corpus")
    assert t.num_rows == 10_000
    toks = np.asarray(t.columns["token"].data)
    assert toks.dtype == np.int32
    assert toks.min() >= 0 and toks.max() < 512


def test_curation_filters_in_engine(corpus_db):
    n = curate(corpus_db, "corpus", "clean", drop_token=0)
    toks = np.asarray(corpus_db.table("clean").columns["token"].data)
    assert (toks != 0).all()
    assert n == len(toks)


def test_batches_are_shifted_pairs(corpus_db):
    pipe = TokenPipeline(corpus_db, "corpus", batch=2, seq_len=16)
    b = pipe.next_batch()
    assert b["tokens"].shape == (2, 16) and b["labels"].shape == (2, 16)
    # labels are inputs shifted by one within the flat stream
    np.testing.assert_array_equal(b["tokens"][0, 1:], b["labels"][0, :-1])


def test_cursor_advances_and_wraps(corpus_db):
    pipe = TokenPipeline(corpus_db, "corpus", batch=4, seq_len=32)
    per = pipe.tokens_per_batch
    b1 = pipe.next_batch()
    assert pipe.cursor == per
    for _ in range(10_000 // per + 1):      # force a wrap
        pipe.next_batch()
    assert pipe.cursor <= 10_000


def test_state_restore_exactly_once(corpus_db):
    pipe = TokenPipeline(corpus_db, "corpus", batch=2, seq_len=16)
    pipe.next_batch()
    st = pipe.state()
    b_expected = pipe.next_batch()
    # "crash": new pipeline object, restore cursor
    pipe2 = TokenPipeline(corpus_db, "corpus", batch=2, seq_len=16)
    pipe2.restore(st)
    b_replayed = pipe2.next_batch()
    np.testing.assert_array_equal(b_expected["tokens"], b_replayed["tokens"])


def test_restore_rejects_version_mismatch(corpus_db):
    pipe = TokenPipeline(corpus_db, "corpus", batch=2, seq_len=16)
    st = pipe.state()
    corpus_db.append("corpus", {"token": np.array([1], dtype=np.int32)})
    pipe2 = TokenPipeline(corpus_db, "corpus", batch=2, seq_len=16)
    with pytest.raises(RuntimeError, match="version"):
        pipe2.restore(st)


def test_feed_is_zero_copy(corpus_db):
    pipe = TokenPipeline(corpus_db, "corpus", batch=2, seq_len=16)
    col = corpus_db.table("corpus").columns["token"]
    assert np.shares_memory(pipe._view, col.data)


def test_shard_plan_covers_stream(corpus_db):
    pipe = TokenPipeline(corpus_db, "corpus")
    plan = pipe.shard_plan(4)
    assert len(plan) == 4
    assert plan[0][0] == 0
    for (s1, e1), (s2, e2) in zip(plan, plan[1:]):
        assert e1 == s2
