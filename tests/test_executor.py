"""Columnar executor: operators vs numpy expectations, MAL properties."""

import numpy as np
import pytest

from repro.core import Col, Func, startup
from repro.core.executor import compile_plan
from repro.core.optimizer import optimize


@pytest.fixture
def tdb(rng):
    db = startup()
    n = 2000
    db.create_table("t", {
        "k": np.asarray(["a", "b", "c", "d"], dtype=object)[
            rng.integers(0, 4, n)],
        "g": rng.integers(0, 7, n).astype(np.int64),
        "x": rng.uniform(-100, 100, n),
        "y": rng.integers(0, 1000, n).astype(np.int64),
    })
    db.create_table("dim", {
        "g": np.arange(7, dtype=np.int64),
        "label": np.asarray([f"g{i}" for i in range(7)], dtype=object),
        "w": np.arange(7) * 1.5,
    })
    return db


def arrs(db, t):
    tt = db.table(t)
    return {c: np.asarray(tt.columns[c].data) for c in tt.schema.names}, tt


def test_filter_matches_numpy(tdb):
    a, t = arrs(tdb, "t")
    got = tdb.scan("t").filter((Col("x") > 0) & (Col("g") < 3)) \
        .agg(n=("count", None)).execute().to_pydict()
    exp = ((a["x"] > 0) & (a["g"] < 3)).sum()
    assert got["n"][0] == exp


def test_group_by_sums(tdb):
    a, t = arrs(tdb, "t")
    got = tdb.scan("t").group_by("g").agg(s=("sum", "x")) \
        .order_by("g").execute().to_pydict()
    for i, g in enumerate(got["g"]):
        np.testing.assert_allclose(got["s"][i], a["x"][a["g"] == g].sum())


def test_join_inner_matches_numpy(tdb):
    a, _ = arrs(tdb, "t")
    got = tdb.scan("t").join(tdb.scan("dim"), on="g") \
        .agg(s=("sum", "w"), n=("count", None)).execute().to_pydict()
    w = np.arange(7) * 1.5
    np.testing.assert_allclose(got["s"][0], w[a["g"]].sum())
    assert got["n"][0] == len(a["g"])


def test_left_join_fills_null(db):
    db.create_table("l", {"k": np.array([1, 2, 3], dtype=np.int64)})
    db.create_table("r", {"k": np.array([2], dtype=np.int64),
                          "v": np.array([9.0])})
    out = db.scan("l").join(db.scan("r"), on="k", how="left") \
        .order_by("k").execute().to_pydict()
    assert np.isnan(out["v"][0]) and out["v"][1] == 9.0 \
        and np.isnan(out["v"][2])


def test_semi_anti_partition(tdb):
    n = tdb.table("t").num_rows
    semi = tdb.scan("t").join(tdb.scan("dim").filter(Col("w") > 3),
                              on="g", how="semi") \
        .agg(n=("count", None)).execute().to_pydict()["n"][0]
    anti = tdb.scan("t").join(tdb.scan("dim").filter(Col("w") > 3),
                              on="g", how="anti") \
        .agg(n=("count", None)).execute().to_pydict()["n"][0]
    assert semi + anti == n


def test_multi_key_join(db):
    db.create_table("a", {"x": np.array([1, 1, 2], dtype=np.int64),
                          "y": np.array([1, 2, 1], dtype=np.int64)})
    db.create_table("b", {"x": np.array([1, 2], dtype=np.int64),
                          "y": np.array([2, 1], dtype=np.int64),
                          "v": np.array([10.0, 20.0])})
    out = db.scan("a").join(db.scan("b"), on=("x", "y")) \
        .order_by("v").execute().to_pydict()
    assert out["v"].tolist() == [10.0, 20.0]


def test_order_by_desc_limit(tdb):
    a, _ = arrs(tdb, "t")
    got = tdb.scan("t").select("y").order_by(("y", True)).limit(5) \
        .execute().to_pydict()
    exp = np.sort(a["y"])[::-1][:5]
    assert got["y"].tolist() == exp.tolist()


def test_median_blocking_op(tdb):
    a, _ = arrs(tdb, "t")
    got = tdb.scan("t").agg(m=("median", "x")).execute().to_pydict()
    np.testing.assert_allclose(got["m"][0], np.median(a["x"]))


def test_paper_fig2_query(tdb):
    """SELECT MEDIAN(SQRT(i*2)) FROM tbl — the paper's Fig. 2 example."""
    got = tdb.scan("t").project(v=Func("sqrt", Col("y") * 2)) \
        .agg(m=("median", "v")).execute().to_pydict()
    a, _ = arrs(tdb, "t")
    np.testing.assert_allclose(got["m"][0],
                               np.median(np.sqrt(a["y"] * 2.0)))


def test_count_distinct_and_var(tdb):
    a, _ = arrs(tdb, "t")
    got = tdb.scan("t").agg(cd=("count_distinct", "g"),
                            v=("var", "x")).execute().to_pydict()
    assert got["cd"][0] == len(np.unique(a["g"]))
    np.testing.assert_allclose(got["v"][0], a["x"].var(), rtol=1e-9)


def test_min_max_preserve_int_type(tdb):
    got = tdb.scan("t").group_by("k").agg(mx=("max", "y")) \
        .execute()
    from repro.core.types import DBType
    assert got.columns["mx"].dbtype == DBType.INT64


def test_mal_cse_dedupes(tdb):
    q = tdb.scan("t").project(a=Col("x") * 2, b=Col("x") * 2)
    plan = optimize(q.plan, tdb.catalog)
    prog = compile_plan(plan, tdb.catalog)
    exprs = [i for i in prog.instrs if i.op == "expr"]
    assert len(exprs) == 1          # identical expressions share a register


def test_mal_listing_marks_parallelizable(tdb):
    q = tdb.scan("t").filter(Col("x") > 0).group_by("k").agg(
        n=("count", None))
    prog = compile_plan(optimize(q.plan, tdb.catalog), tdb.catalog)
    listing = prog.listing()
    assert "[P]" in listing and "[B]" in listing
    ops = {i.op for i in prog.instrs}
    assert "select" in ops and "group" in ops


def test_optimized_equals_unoptimized(tdb):
    q = (tdb.scan("t")
         .join(tdb.scan("dim"), on="g")
         .filter((Col("x") > -50) & (Col("label") != "g3"))
         .group_by("k").agg(s=("sum", Col("x") * Col("w")),
                            n=("count", None))
         .order_by("k"))
    a = q.execute(do_optimize=True).to_pydict()
    b = q.execute(do_optimize=False).to_pydict()
    for key in a:
        if a[key].dtype == object:
            assert list(a[key]) == list(b[key])
        else:
            np.testing.assert_allclose(a[key].astype(float),
                                       b[key].astype(float), rtol=1e-12)


def test_executor_stats(tdb):
    tdb.scan("t").filter(Col("x") > 0).agg(n=("count", None)).execute()
    assert tdb.last_stats.instructions > 0
    assert tdb.last_stats.rows_scanned >= tdb.table("t").num_rows
