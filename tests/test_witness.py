"""Lock-order witness: unit tests for the graph recorder and an
integration pass instrumenting a real Database under concurrent queries
(the acquisition graph must come back acyclic with no held-lock waits)."""

import threading

import numpy as np
import pytest

from repro.analysis.witness import (LockOrderError, LockWitness,
                                    _WitnessedLock, install,
                                    instrument_database, uninstall)
from repro.core import startup
from repro.core.expression import Col


class TestWitnessGraph:
    def test_consistent_order_is_acyclic(self):
        w = LockWitness()
        a = _WitnessedLock(threading.Lock(), "A", w)
        b = _WitnessedLock(threading.Lock(), "B", w)

        def use():
            with a:
                with b:
                    pass

        use()
        t = threading.Thread(target=use)
        t.start()
        t.join(10)
        assert ("A", "B") in w.edges
        assert w.cycles() == []
        w.assert_ok()

    def test_inverted_order_reports_cycle(self):
        w = LockWitness()
        a = _WitnessedLock(threading.Lock(), "A", w)
        b = _WitnessedLock(threading.Lock(), "B", w)
        with a:
            with b:
                pass

        def inverted():           # runs after main released both: no
            with b:               # deadlock, but the A<->B cycle is real
                with a:
                    pass

        t = threading.Thread(target=inverted)
        t.start()
        t.join(10)
        assert w.cycles(), w.report()
        with pytest.raises(LockOrderError):
            w.assert_ok()

    def test_reentrant_acquire_is_not_an_edge(self):
        w = LockWitness()
        r = _WitnessedLock(threading.RLock(), "R", w)
        with r:
            with r:               # RLock reentrancy: no R -> R self-edge
                pass
        assert w.edges == {}
        w.assert_ok()

    def test_wait_with_other_lock_held_is_flagged(self):
        w = LockWitness()
        lk = _WitnessedLock(threading.Lock(), "L", w)
        cond = _WitnessedLock(threading.Condition(), "C", w)
        with lk:
            with cond:
                cond.wait(0.01)   # L stays held for the whole wait
        assert w.wait_violations, w.report()
        with pytest.raises(LockOrderError):
            w.assert_ok()

    def test_wait_on_own_cond_alone_is_fine(self):
        w = LockWitness()
        cond = _WitnessedLock(threading.Condition(), "C", w)
        with cond:
            cond.wait(0.01)       # the cond's own lock is released by wait
        assert w.wait_violations == []
        w.assert_ok()

    def test_deadlock_edge_recorded_before_blocking(self):
        # note_acquire runs before the inner acquire can block, so even a
        # wedged thread leaves its intent in the graph
        w = LockWitness()
        a = _WitnessedLock(threading.Lock(), "A", w)
        w.note_acquire("A")       # simulate: thread announces, then blocks
        assert w.acquire_count == 1
        with a:
            pass
        w.assert_ok()


class TestManagerInstrumentation:
    def test_buffer_manager_locks_are_witnessed(self):
        from repro.core.buffers import BufferManager
        w = LockWitness()
        bm = BufferManager(budget=10_000)

        class _Db:
            buffer_manager = bm

        instrument_database(_Db(), w)
        with bm.query_scope():
            assert bm.try_pin(4_000)
            bm.unpin(4_000)
        bm.cleanup()
        assert w.acquire_count > 0
        assert not w.cycles()
        w.assert_ok()


class TestEngineIntegration:
    def test_concurrent_queries_acyclic(self):
        w = LockWitness()
        install(w)
        try:
            db = startup(memory_budget=8 << 20)
            n = 50_000
            rng = np.random.default_rng(3)
            db.create_table("t", {
                "k": (np.arange(n) % 13).astype(np.int64),
                "v": rng.standard_normal(n),
            })
            errors = []

            def worker():
                try:
                    for _ in range(3):
                        r = db.scan("t").group_by("k").agg(
                            s=("sum", Col("v"))).execute()
                        assert r.num_rows == 13
                except Exception as e:      # noqa: BLE001
                    errors.append(e)

            ts = [threading.Thread(target=worker) for _ in range(4)]
            for t in ts:
                t.start()
            for t in ts:
                t.join(120)
            assert not errors, errors
            db.shutdown()
        finally:
            uninstall()
        assert w.acquire_count > 0, "witness saw no lock traffic"
        assert w.cycles() == [], w.report()
        assert w.wait_violations == [], w.report()
        w.assert_ok()
