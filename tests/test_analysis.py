"""Golden tests for the invariant linter: every checker flags its seeded
bug fixture (on exactly the ``# BAD`` lines), and the full pass runs
clean on the real tree."""

import os
import subprocess
import sys

from repro.analysis import run_lint
from repro.analysis.checkers import CHECKERS
from repro.analysis.core import SourceFile, in_core
from repro.analysis.lint import main as lint_main

HERE = os.path.dirname(os.path.abspath(__file__))
ROOT = os.path.dirname(HERE)
FIXTURES = os.path.join(HERE, "lint_fixtures")

# fixture -> the one rule its seeded bugs must trip
GOLDEN = {
    "bad_guarded.py": "guarded-by",
    "bad_toctou.py": "check-then-act",
    "bad_pairing.py": "acquire-release",
    "bad_dispatch.py": "device-dispatch",
    "bad_stats.py": "stats-discipline",
}


def _bad_lines(path):
    with open(path) as f:
        return {i for i, ln in enumerate(f, start=1) if "# BAD" in ln}


class TestGoldenFixtures:
    def test_every_checker_has_a_fixture(self):
        assert sorted(GOLDEN.values()) == sorted(c.rule for c in CHECKERS)
        assert len(CHECKERS) >= 5

    def test_each_fixture_flags_its_rule_on_the_bad_lines(self):
        for fname, rule in GOLDEN.items():
            path = os.path.join(FIXTURES, fname)
            findings = run_lint([path])
            assert findings, f"{fname}: seeded bug not flagged"
            assert {f.rule for f in findings} == {rule}, \
                f"{fname}: {[str(f) for f in findings]}"
            assert {f.line for f in findings} == _bad_lines(path), \
                f"{fname}: flagged lines != # BAD lines: " \
                f"{[str(f) for f in findings]}"

    def test_pre_pr6_toctou_reconstruction(self):
        """The reconstructed would_exceed()+pin() pair is caught and the
        message points at the atomic replacement."""
        findings = run_lint([os.path.join(FIXTURES, "bad_toctou.py")])
        assert len(findings) == 1
        assert findings[0].rule == "check-then-act"
        assert "try_pin" in findings[0].message
        assert "pin()" in findings[0].message

    def test_rule_filter(self):
        path = os.path.join(FIXTURES, "bad_pairing.py")
        assert run_lint([path], rules=["guarded-by"]) == []
        assert len(run_lint([path], rules=["acquire-release"])) == 2


class TestCleanTree:
    def test_core_is_clean(self):
        findings = run_lint([os.path.join(ROOT, "src", "repro", "core")])
        assert findings == [], "\n".join(str(f) for f in findings)

    def test_whole_src_is_clean(self):
        findings = run_lint([os.path.join(ROOT, "src")])
        assert findings == [], "\n".join(str(f) for f in findings)

    def test_core_carries_no_suppressions(self):
        core = os.path.join(ROOT, "src", "repro", "core")
        for fname in os.listdir(core):
            if not fname.endswith(".py"):
                continue
            src = SourceFile(os.path.join(core, fname))
            assert not src.ignores, \
                f"{fname} uses lint: ignore[...] — fix the code instead"


class TestCli:
    def test_exit_codes(self):
        assert lint_main([os.path.join(ROOT, "src")]) == 0
        assert lint_main([FIXTURES]) == 1
        assert lint_main(["--list"]) == 0

    def test_module_invocation(self):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.path.join(ROOT, "src") + os.pathsep \
            + env.get("PYTHONPATH", "")
        proc = subprocess.run(
            [sys.executable, "-m", "repro.analysis.lint", "src/"],
            cwd=ROOT, env=env, capture_output=True, text=True)
        assert proc.returncode == 0, proc.stdout + proc.stderr


class TestFramework:
    def test_nested_defs_inherit_no_locks(self):
        src = SourceFile("<mem>", text=(
            "class C:\n"
            "    def m(self):\n"
            "        with self._lock:\n"
            "            def later():\n"
            "                return self._entries\n"
            "            return later\n"))
        # the nested def's body runs after the with exits
        src.comment_guards["_entries"] = ("C", "_lock")
        from repro.analysis.checkers import check_guarded_by
        findings = check_guarded_by(src)
        assert len(findings) == 1 and findings[0].line == 5

    def test_requires_lock_annotation_satisfies_guard(self):
        src = SourceFile("<mem>", text=(
            "class C:\n"
            "    def m(self):  # requires-lock: _lock\n"
            "        return self._entries\n"))
        src.comment_guards["_entries"] = ("C", "_lock")
        from repro.analysis.checkers import check_guarded_by
        assert check_guarded_by(src) == []

    def test_ignore_directive_suppresses(self, tmp_path):
        p = tmp_path / "mod.py"
        p.write_text(
            "def f(bufman):\n"
            "    bufman.stats.hits += 1  # lint: ignore[stats-discipline]\n")
        assert run_lint([str(p)]) == []

    def test_in_core_scoping(self):
        assert in_core("src/repro/core/spill.py")
        assert in_core("tests/lint_fixtures/bad_stats.py")
        assert not in_core("src/repro/models/transformer.py")
