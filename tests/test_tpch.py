"""TPC-H Q1-Q10 (paper Table 1): engine vs volcano differential + oracles.

The columnar engine and the volcano interpreter are independent
implementations sharing only the logical plans, so agreement is a strong
correctness check.  Q1/Q6 additionally check against hand-written numpy
oracles, and the SQL variants must match the builder plans.
"""

import numpy as np
import pytest

from repro.core import startup
from repro.core.optimizer import optimize
from repro.core.volcano import VolcanoExecutor
from repro.data import tpch
from repro.data.tpch_queries import ALL_QUERIES, SQL_QUERIES
from repro.core.types import date_from_string

SF = 0.002


@pytest.fixture(scope="module")
def tpchdb():
    db = startup()
    tpch.load_into(db, sf=SF, seed=3)
    return db


def _table_to_rows(table):
    d = table.to_pydict()
    names = list(d)
    return [dict(zip(names, vals)) for vals in zip(*d.values())]


def _close(a, b):
    if a is None and b is None:
        return True
    if isinstance(a, float) and isinstance(b, float) \
            and np.isnan(a) and np.isnan(b):
        return True
    if isinstance(a, (float, np.floating)) or isinstance(b, (float, np.floating)):
        return bool(np.isclose(float(a), float(b), rtol=1e-8, atol=1e-8))
    return a == b


@pytest.mark.parametrize("qname", list(ALL_QUERIES))
def test_engine_vs_volcano(tpchdb, qname):
    q = ALL_QUERIES[qname](tpchdb)
    engine_rows = _table_to_rows(q.execute())
    plan = optimize(q.plan, tpchdb.catalog)
    volcano_rows = VolcanoExecutor(tpchdb).execute(plan)
    assert len(engine_rows) == len(volcano_rows), qname
    # Order-insensitive compare for unordered tails with ties
    keyf = lambda r: tuple(str(v) for v in r.values())
    for er, vr in zip(sorted(engine_rows, key=keyf),
                      sorted(volcano_rows, key=keyf)):
        assert set(er) == set(vr), qname
        for c in er:
            assert _close(er[c], vr[c]), (qname, c, er[c], vr[c])


def test_q1_numpy_oracle(tpchdb):
    li = tpchdb.table("lineitem")
    ship = np.asarray(li.columns["l_shipdate"].data)
    keep = ship <= int(date_from_string("1998-09-02"))
    rf = li.columns["l_returnflag"].to_numpy()[keep]
    ls = li.columns["l_linestatus"].to_numpy()[keep]
    qty = np.asarray(li.columns["l_quantity"].data)[keep]
    price = np.asarray(li.columns["l_extendedprice"].data)[keep] / 100.0
    disc = np.asarray(li.columns["l_discount"].data)[keep]
    got = ALL_QUERIES["q1"](tpchdb).execute().to_pydict()
    for i in range(len(got["l_returnflag"])):
        m = (rf == got["l_returnflag"][i]) & (ls == got["l_linestatus"][i])
        np.testing.assert_allclose(got["sum_qty"][i], qty[m].sum(),
                                   rtol=1e-9)
        np.testing.assert_allclose(got["sum_base_price"][i],
                                   price[m].sum(), rtol=1e-9)
        np.testing.assert_allclose(
            got["sum_disc_price"][i],
            (price[m] * (1 - disc[m])).sum(), rtol=1e-9)
        assert got["count_order"][i] == m.sum()


def test_q6_numpy_oracle(tpchdb):
    li = tpchdb.table("lineitem")
    ship = np.asarray(li.columns["l_shipdate"].data)
    disc = np.asarray(li.columns["l_discount"].data)
    qty = np.asarray(li.columns["l_quantity"].data)
    price = np.asarray(li.columns["l_extendedprice"].data) / 100.0
    m = ((ship >= int(date_from_string("1994-01-01")))
         & (ship < int(date_from_string("1995-01-01")))
         & (disc >= 0.05) & (disc <= 0.07) & (qty < 24))
    got = ALL_QUERIES["q6"](tpchdb).execute().to_pydict()
    np.testing.assert_allclose(got["revenue"][0],
                               (price[m] * disc[m]).sum(), rtol=1e-9)


@pytest.mark.parametrize("qname", list(SQL_QUERIES))
def test_sql_matches_builder(tpchdb, qname):
    sql_rows = tpchdb.connect().query(SQL_QUERIES[qname]).to_pydict()
    b_rows = ALL_QUERIES[qname](tpchdb).execute().to_pydict()
    for col in b_rows:
        a, b = sql_rows[col], b_rows[col]
        if a.dtype == object:
            assert list(map(str, a)) == list(map(str, b))
        else:
            np.testing.assert_allclose(a.astype(float), b.astype(float),
                                       rtol=1e-9)


def test_q6_fused_kernel_path(tpchdb):
    """The scan_agg Pallas kernel computes Q6 (fused filter+agg)."""
    from repro.kernels.scan_agg import ops
    li = tpchdb.table("lineitem")
    cols = np.stack([
        np.asarray(li.columns["l_shipdate"].data).astype(np.float64),
        np.asarray(li.columns["l_discount"].data),
        np.asarray(li.columns["l_quantity"].data),
        np.asarray(li.columns["l_extendedprice"].data) / 100.0,
    ])
    d0 = int(date_from_string("1994-01-01"))
    d1 = int(date_from_string("1995-01-01"))
    ranges = np.array([[d0, d1 - 1], [0.05, 0.07], [-np.inf, 23.999],
                       [-np.inf, np.inf]])
    out = ops.fused_filter_agg(cols, ranges, ((3, 1),), interpret=True)
    exp = ALL_QUERIES["q6"](tpchdb).execute().to_pydict()["revenue"][0]
    np.testing.assert_allclose(out[0], exp, rtol=1e-3)  # f32 kernel


def test_distributed_q6(tpchdb):
    q = ALL_QUERIES["q6"](tpchdb)
    a = q.execute().to_pydict()
    b = q.execute(distributed=True).to_pydict()
    np.testing.assert_allclose(a["revenue"], b["revenue"], rtol=1e-9)


# ---- out-of-core golden runs (spill tier vs in-memory tier) ----------------

# Small enough that every blocking operator with non-trivial state
# (the Q3 joins and sort, the high-cardinality groupings below) spills.
SPILL_BUDGET = 16 << 10


@pytest.fixture(scope="module")
def tpchdb_budget():
    db = startup(memory_budget=SPILL_BUDGET)
    tpch.load_into(db, sf=SF, seed=3)
    return db


def _assert_golden(a: dict, b: dict, ctx: str):
    for col in a:
        if a[col].dtype == object:
            assert list(map(str, a[col])) == list(map(str, b[col])), \
                (ctx, col)
        else:
            np.testing.assert_array_equal(a[col], b[col],
                                          err_msg=f"{ctx} {col}")


@pytest.mark.parametrize("qname", ["q1", "q3"])
@pytest.mark.outofcore
def test_golden_under_budget(tpchdb, tpchdb_budget, qname):
    """Q1/Q3 under a 16 KiB budget: byte-identical to the unbudgeted run."""
    a = ALL_QUERIES[qname](tpchdb).execute().to_pydict()
    b = ALL_QUERIES[qname](tpchdb_budget).execute().to_pydict()
    _assert_golden(a, b, qname)


@pytest.mark.outofcore
def test_q1_style_spills_grouping_and_sort(tpchdb, tpchdb_budget):
    """Q1 shape with a high-cardinality key (order-grain): the grouping
    state and the sort both exceed the budget and must spill."""
    from repro.core import Col, DateLit
    q = lambda d: (d.scan("lineitem")
                   .filter(Col("l_shipdate") <= DateLit("1998-09-02"))
                   .group_by("l_orderkey")
                   .agg(sum_qty=("sum", Col("l_quantity")),
                        n=("count", None))
                   .order_by(("sum_qty", True), "l_orderkey"))
    before = tpchdb_budget.buffer_manager.stats.spilled_ops
    _assert_golden(q(tpchdb).execute().to_pydict(),
                   q(tpchdb_budget).execute().to_pydict(), "q1-style")
    assert tpchdb_budget.buffer_manager.stats.spilled_ops - before >= 2
    assert tpchdb_budget.buffer_manager.active_files == 0


@pytest.mark.outofcore
def test_q3_style_spills_every_blocking_op(tpchdb, tpchdb_budget):
    """Q3 shape kept at order grain so join, grouping AND sort all carry
    over-budget state -> all three blocking operators spill."""
    from repro.core import Col
    rev = Col("l_extendedprice") * (1 - Col("l_discount"))
    q = lambda d: (d.scan("orders")
                   .join(d.scan("lineitem"), left_on="o_orderkey",
                         right_on="l_orderkey")
                   .group_by("l_orderkey", "o_orderdate")
                   .agg(revenue=("sum", rev))
                   .order_by(("revenue", True), "l_orderkey"))
    before = tpchdb_budget.buffer_manager.stats.spilled_ops
    _assert_golden(q(tpchdb).execute().to_pydict(),
                   q(tpchdb_budget).execute().to_pydict(), "q3-style")
    assert tpchdb_budget.buffer_manager.stats.spilled_ops - before >= 3
    assert tpchdb_budget.buffer_manager.active_files == 0
    assert tpchdb_budget.buffer_manager.stats.peak <= SPILL_BUDGET
