"""Pallas kernels: interpret-mode shape/dtype sweeps vs ref.py oracles."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.hash_group import ops as hops
from repro.kernels.hash_group.hash_group import hash_group_call
from repro.kernels.hash_group.ref import hash_group_ref
from repro.kernels.imprint import ops as iops
from repro.kernels.imprint.imprint import zone_maps_pallas
from repro.kernels.imprint.ref import zone_maps_ref
from repro.kernels.scan_agg import ops as sops
from repro.kernels.scan_agg.ref import scan_agg_ref
from repro.kernels.scan_agg.scan_agg import scan_agg_pallas


# ---------------------------------------------------------------------------
# imprint
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,null_frac", [(100, 0.0), (5000, 0.1),
                                         (20480, 0.5), (2048, 1.0)])
def test_imprint_kernel_vs_ref(rng, n, null_frac):
    vals = rng.uniform(-100, 100, n)
    nulls = rng.random(n) < null_frac
    v2d, ok2d, nb = iops._prepare(vals, nulls, 2048)
    lo, hi, inv = iops._range(vals, nulls, 16)
    r = jnp.asarray([[lo, inv]], dtype=jnp.float32)
    ref = zone_maps_ref(jnp.asarray(v2d), jnp.asarray(ok2d), r)
    ker = zone_maps_pallas(jnp.asarray(v2d), jnp.asarray(ok2d), r,
                           block_rows=2048, interpret=True)
    for a, b in zip(ref, ker):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_imprint_host_vs_pallas_semantics(rng):
    vals = rng.normal(0, 10, 12345)
    nulls = rng.random(12345) < 0.2
    mh = iops.build_zone_maps(vals, nulls, 2048, 16)
    mp = iops.build_zone_maps_pallas(vals, nulls, 2048, 16, interpret=True)
    assert (mh[2] == mp[2]).all()
    assert (mp[0] <= mh[0]).all() and (mp[1] >= mh[1]).all()  # conservative


# ---------------------------------------------------------------------------
# scan_agg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("C,n,block", [(1, 100, 1024), (3, 9000, 2048),
                                       (6, 40000, 8192)])
def test_scan_agg_sweep(rng, C, n, block):
    cols = rng.uniform(-10, 10, (C, n))
    ranges = np.full((C, 2), (-np.inf, np.inf))
    ranges[0] = (-5, 5)
    pairs = tuple((i, (i + 1) % C if C > 1 else -1) for i in range(C))
    out = sops.fused_filter_agg(cols, ranges, pairs, block_rows=block,
                                interpret=True)
    mask = (cols[0] >= -5) & (cols[0] <= 5)
    for p, (a, b) in enumerate(pairs):
        v = cols[a] if b < 0 else cols[a] * cols[b]
        np.testing.assert_allclose(out[p], v[mask].sum(), rtol=2e-4)
    assert out[-1] == mask.sum()


def test_scan_agg_kernel_vs_ref(rng):
    C, n = 4, 16384
    cols = rng.uniform(0, 100, (C, n)).astype(np.float32)
    ranges = np.array([[10, 90], [-np.inf, np.inf],
                       [0, 50], [-np.inf, np.inf]], dtype=np.float32)
    pairs = ((1, 3), (2, -1))
    # kernel path vs pure-jnp oracle on identical padded inputs
    out_k = sops.fused_filter_agg(cols, ranges, pairs, interpret=True)
    ref = np.asarray(scan_agg_ref(jnp.asarray(cols), jnp.asarray(ranges),
                                  pairs=pairs))
    np.testing.assert_allclose(out_k[:3], ref, rtol=2e-4)


def test_scan_agg_no_filters_counts_all(rng):
    cols = rng.uniform(0, 1, (2, 5000))
    ranges = np.full((2, 2), (-np.inf, np.inf))
    out = sops.fused_filter_agg(cols, ranges, ((0, -1),), interpret=True)
    assert out[-1] == 5000


def test_scan_agg_host_mirror_matches(rng):
    cols = rng.uniform(0, 1, (3, 4096))
    ranges = np.array([[0.2, 0.8], [-np.inf, np.inf], [-np.inf, np.inf]])
    pairs = ((1, 2),)
    a = sops.fused_filter_agg(cols, ranges, pairs, interpret=True)
    b = sops.fused_filter_agg(cols, ranges, pairs, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=2e-4)


# ---------------------------------------------------------------------------
# hash_group
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,G,V", [(100, 3, 1), (4096, 37, 4),
                                   (10000, 256, 2)])
def test_hash_group_sweep(rng, n, G, V):
    gid = rng.integers(0, G, n)
    vals = rng.normal(size=(V, n))
    mask = rng.random(n) < 0.7
    acc = hops.grouped_aggregate(gid, vals, G, mask=mask, interpret=True)
    for g in range(G):
        m = (gid == g) & mask
        np.testing.assert_allclose(acc[g, :V], vals[:, m].sum(axis=1),
                                   atol=1e-3)
        assert acc[g, V] == m.sum()


def test_hash_group_kernel_vs_ref(rng):
    n, G, V = 8192, 64, 8
    gid = rng.integers(0, G, n).astype(np.int32)
    vals = rng.normal(size=(V, n)).astype(np.float32)
    g_pad = 64
    out_k = np.asarray(hash_group_call(jnp.asarray(gid[None]),
                                       jnp.asarray(vals), g_pad,
                                       block_rows=2048, interpret=True))
    out_r = np.asarray(hash_group_ref(jnp.asarray(gid[None]),
                                      jnp.asarray(vals), g_pad))
    np.testing.assert_allclose(out_k, out_r, atol=1e-2)


def test_hash_group_dtypes(rng):
    # int64 inputs cast through float32 path
    gid = rng.integers(0, 5, 1000)
    vals = rng.integers(0, 100, (2, 1000)).astype(np.int64)
    acc = hops.grouped_aggregate(gid, vals.astype(np.float64), 5,
                                 interpret=True)
    for g in range(5):
        np.testing.assert_allclose(acc[g, :2],
                                   vals[:, gid == g].sum(axis=1), rtol=1e-5)
