"""Pallas kernels: interpret-mode shape/dtype sweeps vs ref.py oracles."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.hash_group import ops as hops
from repro.kernels.hash_group.hash_group import hash_group_call
from repro.kernels.hash_group.ref import hash_group_ref
from repro.kernels.imprint import ops as iops
from repro.kernels.imprint.imprint import zone_maps_pallas
from repro.kernels.imprint.ref import zone_maps_ref
from repro.kernels.scan_agg import ops as sops
from repro.kernels.scan_agg.ref import scan_agg_ref
from repro.kernels.scan_agg.scan_agg import scan_agg_pallas


# ---------------------------------------------------------------------------
# imprint
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,null_frac", [(100, 0.0), (5000, 0.1),
                                         (20480, 0.5), (2048, 1.0)])
def test_imprint_kernel_vs_ref(rng, n, null_frac):
    vals = rng.uniform(-100, 100, n)
    nulls = rng.random(n) < null_frac
    v2d, ok2d, nb = iops._prepare(vals, nulls, 2048)
    lo, hi, inv = iops._range(vals, nulls, 16)
    r = jnp.asarray([[lo, inv]], dtype=jnp.float32)
    ref = zone_maps_ref(jnp.asarray(v2d), jnp.asarray(ok2d), r)
    ker = zone_maps_pallas(jnp.asarray(v2d), jnp.asarray(ok2d), r,
                           block_rows=2048, interpret=True)
    for a, b in zip(ref, ker):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_imprint_host_vs_pallas_semantics(rng):
    vals = rng.normal(0, 10, 12345)
    nulls = rng.random(12345) < 0.2
    mh = iops.build_zone_maps(vals, nulls, 2048, 16)
    mp = iops.build_zone_maps_pallas(vals, nulls, 2048, 16, interpret=True)
    assert (mh[2] == mp[2]).all()
    assert (mp[0] <= mh[0]).all() and (mp[1] >= mh[1]).all()  # conservative


# ---------------------------------------------------------------------------
# scan_agg
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("C,n,block", [(1, 100, 1024), (3, 9000, 2048),
                                       (6, 40000, 8192)])
def test_scan_agg_sweep(rng, C, n, block):
    cols = rng.uniform(-10, 10, (C, n))
    ranges = np.full((C, 2), (-np.inf, np.inf))
    ranges[0] = (-5, 5)
    pairs = tuple((i, (i + 1) % C if C > 1 else -1) for i in range(C))
    out = sops.fused_filter_agg(cols, ranges, pairs, block_rows=block,
                                interpret=True)
    mask = (cols[0] >= -5) & (cols[0] <= 5)
    for p, (a, b) in enumerate(pairs):
        v = cols[a] if b < 0 else cols[a] * cols[b]
        np.testing.assert_allclose(out[p], v[mask].sum(), rtol=2e-4)
    assert out[-1] == mask.sum()


def test_scan_agg_kernel_vs_ref(rng):
    C, n = 4, 16384
    cols = rng.uniform(0, 100, (C, n)).astype(np.float32)
    ranges = np.array([[10, 90], [-np.inf, np.inf],
                       [0, 50], [-np.inf, np.inf]], dtype=np.float32)
    pairs = ((1, 3), (2, -1))
    # kernel path vs pure-jnp oracle on identical padded inputs
    out_k = sops.fused_filter_agg(cols, ranges, pairs, interpret=True)
    ref = np.asarray(scan_agg_ref(jnp.asarray(cols), jnp.asarray(ranges),
                                  pairs=pairs))
    np.testing.assert_allclose(out_k[:3], ref, rtol=2e-4)


def test_scan_agg_no_filters_counts_all(rng):
    cols = rng.uniform(0, 1, (2, 5000))
    ranges = np.full((2, 2), (-np.inf, np.inf))
    out = sops.fused_filter_agg(cols, ranges, ((0, -1),), interpret=True)
    assert out[-1] == 5000


def test_scan_agg_host_mirror_matches(rng):
    cols = rng.uniform(0, 1, (3, 4096))
    ranges = np.array([[0.2, 0.8], [-np.inf, np.inf], [-np.inf, np.inf]])
    pairs = ((1, 2),)
    a = sops.fused_filter_agg(cols, ranges, pairs, interpret=True)
    b = sops.fused_filter_agg(cols, ranges, pairs, use_pallas=False)
    np.testing.assert_allclose(a, b, rtol=2e-4)


# ---------------------------------------------------------------------------
# hash_group
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,G,V", [(100, 3, 1), (4096, 37, 4),
                                   (10000, 256, 2)])
def test_hash_group_sweep(rng, n, G, V):
    gid = rng.integers(0, G, n)
    vals = rng.normal(size=(V, n))
    mask = rng.random(n) < 0.7
    acc = hops.grouped_aggregate(gid, vals, G, mask=mask, interpret=True)
    for g in range(G):
        m = (gid == g) & mask
        np.testing.assert_allclose(acc[g, :V], vals[:, m].sum(axis=1),
                                   atol=1e-3)
        assert acc[g, V] == m.sum()


def test_hash_group_kernel_vs_ref(rng):
    n, G, V = 8192, 64, 8
    gid = rng.integers(0, G, n).astype(np.int32)
    vals = rng.normal(size=(V, n)).astype(np.float32)
    g_pad = 64
    out_k = np.asarray(hash_group_call(jnp.asarray(gid[None]),
                                       jnp.asarray(vals), g_pad,
                                       block_rows=2048, interpret=True))
    out_r = np.asarray(hash_group_ref(jnp.asarray(gid[None]),
                                      jnp.asarray(vals), g_pad))
    np.testing.assert_allclose(out_k, out_r, atol=1e-2)


def test_hash_group_dtypes(rng):
    # int64 inputs cast through float32 path
    gid = rng.integers(0, 5, 1000)
    vals = rng.integers(0, 100, (2, 1000)).astype(np.int64)
    acc = hops.grouped_aggregate(gid, vals.astype(np.float64), 5,
                                 interpret=True)
    for g in range(5):
        np.testing.assert_allclose(acc[g, :2],
                                   vals[:, gid == g].sum(axis=1), rtol=1e-5)


# ---------------------------------------------------------------------------
# radix_join
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("nb,np_,V,n_bits", [(50, 200, 1, 2),
                                             (1000, 8000, 3, 4),
                                             (4096, 20000, 2, 5)])
def test_radix_join_sweep(rng, nb, np_, V, n_bits):
    """Pallas partition/build/probe vs the dense un-partitioned oracle:
    matched bits and gathered payload identical for every probe row
    (including misses, which must gather zeros)."""
    from repro.kernels.radix_join.ops import radix_join
    from repro.kernels.radix_join.ref import radix_join_ref
    bk = rng.choice(3 * nb, size=nb, replace=False).astype(np.int64)
    bv = rng.normal(size=(V, nb))
    pk = rng.integers(0, 3 * nb, np_).astype(np.int64)
    m, g = radix_join(bk, bv, pk, n_bits=n_bits, interpret=True)
    mr, gr = radix_join_ref(jnp.asarray(bk), jnp.asarray(bv),
                            jnp.asarray(pk), 3 * nb)
    np.testing.assert_array_equal(m, np.asarray(mr))
    np.testing.assert_allclose(g, np.asarray(gr), atol=1e-5)


def test_radix_join_pallas_vs_numpy_mirror(rng):
    """use_pallas=False runs the identical partition plan in numpy — the
    two paths must agree bit-for-bit on the match mask."""
    from repro.kernels.radix_join.ops import radix_join
    bk = rng.choice(5000, size=800, replace=False).astype(np.int64)
    bv = rng.normal(size=(2, 800))
    pk = rng.integers(-10, 5100, 6000).astype(np.int64)   # incl. misses
    mp, gp = radix_join(bk, bv, pk, n_bits=3, interpret=True)
    mn, gn = radix_join(bk, bv, pk, n_bits=3, use_pallas=False)
    np.testing.assert_array_equal(mp, mn)
    np.testing.assert_allclose(gp, gn, atol=1e-5)


def test_radix_join_negative_domain(rng):
    """Key domains are rebased by the shim: negative key values join
    correctly (the engine's DATE/offset domains)."""
    from repro.kernels.radix_join.ops import radix_join
    bk = (np.arange(64) - 32).astype(np.int64)
    bv = np.arange(64, dtype=np.float64)[None, :]
    pk = np.asarray([-32, -1, 0, 31, 99], dtype=np.int64)
    m, g = radix_join(bk, bv, pk, n_bits=2, interpret=True)
    np.testing.assert_array_equal(m, [True, True, True, True, False])
    np.testing.assert_allclose(g[:, 0], [0, 31, 32, 63, 0], atol=1e-5)


# ---------------------------------------------------------------------------
# sort
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n", [2, 100, 1024, 5000])
def test_sort_block_sweep(rng, n):
    """Bitonic network vs the stable-argsort oracle and the numpy mirror:
    NaNs last, ties broken by original index."""
    from repro.kernels.sort.ops import sort_block
    keys = rng.normal(size=n).astype(np.float32)
    keys[rng.random(n) < 0.1] = np.nan
    keys[rng.random(n) < 0.3] = 1.25          # heavy ties
    sk, si = sort_block(keys, interpret=True)
    sn, sin = sort_block(keys, use_pallas=False)
    np.testing.assert_array_equal(sk, sn)
    np.testing.assert_array_equal(si, sin)


def test_sort_block_kernel_vs_ref(rng):
    from repro.kernels.sort.ops import _next_pow2
    from repro.kernels.sort.ref import bitonic_sort_ref
    from repro.kernels.sort.sort import bitonic_sort_call
    n = 777
    keys = rng.normal(size=n).astype(np.float32)
    n_pad = _next_pow2(n)
    kp = np.full(n_pad, np.inf, dtype=np.float32)
    kp[:n] = keys
    ix = np.arange(n_pad, dtype=np.int32)
    sk, si = bitonic_sort_call(jnp.asarray(kp[None]), jnp.asarray(ix[None]),
                               interpret=True)
    rk, ri = bitonic_sort_ref(jnp.asarray(kp[None]), jnp.asarray(ix[None]))
    np.testing.assert_array_equal(np.asarray(sk), np.asarray(rk))
    np.testing.assert_array_equal(np.asarray(si), np.asarray(ri))


@pytest.mark.parametrize("limit", [None, 10])
def test_lexsort_indices_matches_np(rng, limit):
    """The engine's device lexsort (primary-first keys) vs np.lexsort:
    identical permutation, identical top-N slice."""
    from repro.kernels.sort.ops import lexsort_indices
    # round the primary key so ties force the secondary key to decide
    k0 = np.round(rng.normal(size=4000), 1)
    k1 = rng.integers(0, 50, 4000).astype(np.float64)
    dev = lexsort_indices((k0, k1), limit=limit)
    ref = lexsort_indices((k0, k1), limit=limit, use_device=False)
    np.testing.assert_array_equal(dev, ref)
    want = np.lexsort((k1, k0))
    np.testing.assert_array_equal(ref, want if limit is None
                                  else want[:limit])
