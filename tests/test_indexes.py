"""Imprints (zone maps), order indexes, lifecycle (paper §3.1)."""

import numpy as np
import pytest

from repro.core import Col, startup
from repro.core.indexes import IMPRINT_BLOCK, build_imprint


@pytest.fixture
def idb(rng):
    db = startup()
    n = 50_000
    db.create_table("t", {
        "x": np.sort(rng.uniform(0, 1000, n)),       # clustered -> skippable
        "r": rng.uniform(0, 1000, n),                # random -> few skips
        "k": rng.integers(0, 50, n).astype(np.int64),
    })
    return db


def test_imprint_mask_equals_naive(idb):
    im = idb.index_manager.imprint_mask("t", "x", 100.0, 200.0, False, False)
    assert im is not None
    mask, skipped = im
    x = np.asarray(idb.table("t").columns["x"].data)
    np.testing.assert_array_equal(mask, (x >= 100.0) & (x <= 200.0))


def test_imprint_skips_blocks_on_clustered_data(idb):
    mask, skipped = idb.index_manager.imprint_mask(
        "t", "x", 100.0, 120.0, False, False)
    n_blocks = -(-idb.table("t").num_rows // IMPRINT_BLOCK)
    assert skipped > 0.5 * n_blocks     # most blocks pruned


def test_imprint_strict_bounds(idb):
    x = np.asarray(idb.table("t").columns["x"].data)
    lo = float(np.quantile(x, 0.3))
    mask, _ = idb.index_manager.imprint_mask("t", "x", lo, np.inf,
                                             True, False)
    np.testing.assert_array_equal(mask, x > lo)


def test_imprint_used_by_executor(idb):
    got = idb.scan("t").filter((Col("x") >= 100.0) & (Col("x") <= 200.0)) \
        .agg(n=("count", None)).execute().to_pydict()
    x = np.asarray(idb.table("t").columns["x"].data)
    assert got["n"][0] == ((x >= 100) & (x <= 200)).sum()
    assert idb.last_stats.index_hits >= 1
    assert idb.last_stats.imprint_blocks_skipped > 0


def test_imprint_nulls_excluded(db):
    v = np.arange(5000, dtype=np.float64)
    v[::7] = np.nan
    db.create_table("n", {"v": v})
    im = db.index_manager.imprint_mask("n", "v", 10, 100, False, False)
    mask, _ = im
    expected = (v >= 10) & (v <= 100) & ~np.isnan(v)
    np.testing.assert_array_equal(mask, expected)


def test_order_index_point_lookup(idb):
    rows = idb.index_manager.point_lookup("t", "k", 7)
    k = np.asarray(idb.table("t").columns["k"].data)
    assert sorted(rows.tolist()) == sorted(np.nonzero(k == 7)[0].tolist())


def test_auto_order_index_on_join(idb, rng):
    idb.create_table("probe", {
        "k": rng.integers(0, 50, 5000).astype(np.int64),
        "v": rng.uniform(0, 1, 5000)})
    # join probe (left/big? probe is left) with t: build side = t unfiltered
    got = idb.scan("probe").join(idb.scan("t"), on="k") \
        .agg(n=("count", None)).execute()
    assert idb.last_stats.index_hits >= 1
    # the optimizer picks the smaller side as build side; the auto index
    # lands there (paper: hash tables auto-built on join keys)
    assert (idb.index_manager.get_order_index("t", "k") is not None
            or idb.index_manager.get_order_index("probe", "k") is not None)


def test_index_invalidated_on_append(idb):
    idb.index_manager.create_order_index("t", "k")
    assert idb.index_manager.get_order_index("t", "k") is not None
    idb.append("t", {"x": np.array([1.0]), "r": np.array([2.0]),
                     "k": np.array([3], dtype=np.int64)})
    assert idb.index_manager.get_order_index("t", "k") is None


def test_imprint_pallas_matches_host(rng):
    from repro.kernels.imprint import ops
    vals = rng.uniform(-50, 50, 10_000)
    nulls = rng.random(10_000) < 0.05
    m_host = ops.build_zone_maps(vals, nulls, 2048, 16)
    m_pal = ops.build_zone_maps_pallas(vals, nulls, 2048, 16,
                                       interpret=True)
    assert (m_host[2] == m_pal[2]).all()          # bitmaps identical
    # kernel bounds are conservative (widened by 1 ulp)
    assert (m_pal[0] <= m_host[0] + 1e-3).all()
    assert (m_pal[1] >= m_host[1] - 1e-3).all()


def test_small_columns_not_indexed(db):
    db.create_table("small", {"v": np.arange(10, dtype=np.float64)})
    assert db.index_manager.get_imprint("small", "v") is None


def test_create_order_index_statement(idb):
    """Paper §3.1: the explicit CREATE ORDER INDEX statement."""
    con = idb.connect()
    con.query("CREATE ORDER INDEX idx_k ON t(k)")
    assert idb.index_manager.get_order_index("t", "k") is not None
    # merge-join tactical path now hits the persisted index
    import numpy as np
    idb.create_table("p2", {"k": np.arange(50, dtype=np.int64).repeat(100)})
    idb.scan("p2").join(idb.scan("t"), on="k") \
        .agg(n=("count", None)).execute()
    assert idb.last_stats.index_hits >= 1


def test_db_create_order_index_api(idb):
    perm = idb.create_order_index("t", "x")
    x = __import__("numpy").asarray(idb.table("t").columns["x"].data)
    assert (x[perm[:-1]] <= x[perm[1:]]).all()
