"""Concurrent serving layer: admission gate, plan cache, shared scans, and
the thread-safety bugfixes that unlock them (per-thread last_stats, atomic
try_pin, cleanup deferral)."""

import threading
import time

import numpy as np
import pytest

from repro.core import startup
from repro.core.buffers import BufferManager
from repro.core.expression import Col
from repro.core.serving import (AdmissionGate, AdmissionTimeout, PlanCache,
                                SingleFlight, lower_cached)

MB = 1 << 20


def _mkdb(**kw):
    db = startup(**kw)
    n = 50_000
    rng = np.random.default_rng(7)
    db.create_table("t", {
        "k": (np.arange(n) % 11).astype(np.int64),
        "v": rng.standard_normal(n),
    })
    return db


def _q(db):
    return db.scan("t").group_by("k").agg(s=("sum", Col("v")),
                                          n=("count", None))


# ---------------------------------------------------------------------------
# admission gate
# ---------------------------------------------------------------------------


class TestAdmissionGate:
    def test_immediate_admit_and_release(self):
        g = AdmissionGate(host_budget=1000, device_budget=None)
        with g.admit(400) as t:
            assert g.host_reserved == 400
            assert t.waited == 0.0
        assert g.host_reserved == 0
        assert g.stats.admitted == 1
        assert g.stats.queued == 0

    def test_request_capped_at_budget(self):
        # a plan whose reservations sum past the budget is what the spill
        # tier exists for: it must be admissible when running alone
        g = AdmissionGate(host_budget=1000, device_budget=500)
        with g.admit(10_000, 9_999):
            assert g.host_reserved == 1000
            assert g.device_reserved == 500

    def test_unlimited_budget_reserves_nothing(self):
        g = AdmissionGate(host_budget=None, device_budget=None)
        with g.admit(1 << 40, 1 << 40):
            assert g.host_reserved == 0
            assert g.device_reserved == 0

    def test_queueing_blocks_until_release(self):
        g = AdmissionGate(host_budget=1000, device_budget=None)
        first = g.admit(800)
        order = []

        def second():
            with g.admit(800) as t:
                order.append(("second", t.waited > 0))

        th = threading.Thread(target=second)
        th.start()
        time.sleep(0.1)
        assert not order, "second admission must queue behind the first"
        order.append(("release", None))
        first.release()
        th.join(5)
        assert order == [("release", None), ("second", True)]
        assert g.stats.queued == 1
        assert g.stats.host_reserved_peak == 800

    def test_bounded_wait_times_out(self):
        g = AdmissionGate(host_budget=1000, device_budget=None)
        held = g.admit(900)
        with pytest.raises(AdmissionTimeout):
            g.admit(900, timeout=0.1)
        assert g.stats.timeouts == 1
        held.release()
        with g.admit(900):          # admissible again after the release
            pass

    def test_concurrent_reservations_never_exceed_budget(self):
        g = AdmissionGate(host_budget=1000, device_budget=None)
        peak_ok = []

        def worker():
            for _ in range(20):
                with g.admit(400):
                    peak_ok.append(g.host_reserved <= 1000)

        ts = [threading.Thread(target=worker) for _ in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(30)
        assert all(peak_ok)
        assert g.stats.host_reserved_peak <= 1000
        assert g.host_reserved == 0


# ---------------------------------------------------------------------------
# plan cache
# ---------------------------------------------------------------------------


class TestPlanCache:
    def test_hot_repeat_skips_lowering(self, monkeypatch):
        db = _mkdb()
        q = _q(db)
        r1 = q.execute()
        assert db.last_stats.plan_cache_hit is False
        # fence: a cache hit must not call plan_physical at all
        import repro.core.physplan as physplan

        def boom(*a, **kw):
            raise AssertionError("plan_physical called on a cache hit")

        monkeypatch.setattr(physplan, "plan_physical", boom)
        monkeypatch.setattr("repro.core.serving.plan_physical", boom,
                            raising=False)
        r2 = q.execute()
        assert db.last_stats.plan_cache_hit is True
        assert db.last_stats.plan_repr     # EXPLAIN text still served
        for k in ("k", "s", "n"):
            np.testing.assert_array_equal(
                np.asarray(r1.columns[k].data),
                np.asarray(r2.columns[k].data))
        db.shutdown()

    def test_append_never_serves_stale_plan(self):
        # a delta append does NOT eagerly flush the plan cache (the stale
        # entry ages out by LRU); the (version, base_version, delta_epoch)
        # key component alone must make it unreachable
        db = _mkdb()
        q = _q(db)
        q.execute()
        assert len(db.plan_cache) == 1
        db.append("t", {"k": np.array([1], dtype=np.int64),
                        "v": np.array([2.0])})
        r = q.execute()
        assert db.last_stats.plan_cache_hit is False
        # the appended row is visible through the fresh plan
        assert int(np.asarray(r.columns["n"].data).sum()) == 50_001
        db.shutdown()

    def test_drop_table_invalidates(self):
        db = _mkdb()
        _q(db).execute()
        assert len(db.plan_cache) == 1
        db.drop_table("t")
        assert len(db.plan_cache) == 0
        db.shutdown()

    def test_delete_invalidates(self):
        db = _mkdb()
        _q(db).execute()
        assert len(db.plan_cache) == 1
        db.delete("t", Col("k") == 3)
        assert len(db.plan_cache) == 0
        db.shutdown()

    def test_version_keyed_even_without_invalidation(self):
        # negative control: the explicit invalidation bounds the cache,
        # but correctness must not depend on it — the version component of
        # the key alone must prevent a stale hit
        db = _mkdb()
        q = _q(db)
        q.execute()
        key_before = PlanCache.key(db, q.plan, do_optimize=True,
                                   distributed=False)
        db.append("t", {"k": np.array([1], dtype=np.int64),
                        "v": np.array([2.0])})
        key_after = PlanCache.key(db, q.plan, do_optimize=True,
                                  distributed=False)
        assert key_before != key_after
        db.shutdown()

    def test_budget_change_changes_key(self):
        # two databases over the same data but different budgets must not
        # share physical plans: the annotation (spill vs in-memory) differs
        db_big = _mkdb()
        db_small = _mkdb(memory_budget=64 * 1024)
        try:
            q_big, q_small = _q(db_big), _q(db_small)
            kb = PlanCache.key(db_big, q_big.plan, do_optimize=True,
                               distributed=False)
            ks = PlanCache.key(db_small, q_small.plan, do_optimize=True,
                               distributed=False)
            assert kb != ks
            # stale-plan negative control: serving the big-budget plan to
            # the small-budget database would return wrong tier
            # annotations (everything in-memory, nothing runtime-refined)
            pb, _, _ = lower_cached(db_big, q_big.plan)
            ps, _, _ = lower_cached(db_small, q_small.plan)
            assert pb.policy.host_budget != ps.policy.host_budget
            assert pb.render() != ps.render()
        finally:
            db_big.shutdown()
            db_small.shutdown()

    def test_lru_eviction_bounds_entries(self):
        db = _mkdb()
        db.plan_cache.capacity = 4
        for lim in range(1, 10):
            db.scan("t").limit(lim).execute()
        assert len(db.plan_cache) <= 4
        db.shutdown()

    def test_cardinality_feedback_reaches_planner(self):
        # tight budget: the level-1 estimate says the 50k-row input's
        # grouping state (~1.2MB) spills, but only 11 groups exist.  After
        # one run the observed cardinality feeds back and the plan-time
        # annotation flips to in-memory — matching what actually executes.
        db = _mkdb(memory_budget=256 * 1024)
        q = _q(db)
        q.execute()
        assert db.last_stats.observed_group_card == 11
        db.append("t", {"k": np.array([1], dtype=np.int64),
                        "v": np.array([2.0])})     # invalidate -> re-plan
        q.execute()
        assert "observed groups=11" in db.last_stats.plan_repr
        assert db.last_stats.spilled_ops == 0
        db.shutdown()

    def test_demotion_on_copy_does_not_poison_cache(self):
        db = _mkdb()
        phys1, _, _ = lower_cached(db, _q(db).plan)
        phys1.demote_device("test")
        phys2, _, hit = lower_cached(db, _q(db).plan)
        assert hit is True
        assert phys2.agg_tier != "parallel-host" or phys2.agg_tier is None
        db.shutdown()


# ---------------------------------------------------------------------------
# single flight
# ---------------------------------------------------------------------------


class TestSingleFlight:
    def test_concurrent_callers_share_one_build(self):
        sf = SingleFlight()
        calls = []
        gate = threading.Event()

        def build():
            calls.append(1)
            gate.wait(5)
            return "block"

        results = []

        def caller():
            results.append(sf.do("key", build))

        ts = [threading.Thread(target=caller) for _ in range(4)]
        for t in ts:
            t.start()
        time.sleep(0.2)          # let every caller reach the flight
        gate.set()
        for t in ts:
            t.join(10)
        assert len(calls) == 1, "builder must run exactly once"
        assert sorted(r[0] for r in results) == ["block"] * 4
        assert sum(attached for _, attached in results) == 3
        assert sf.attaches == 3

    def test_builder_failure_does_not_poison_attachers(self):
        sf = SingleFlight()
        attempts = []
        gate = threading.Event()

        def build():
            attempts.append(1)
            if len(attempts) == 1:
                gate.wait(5)
                raise RuntimeError("first build fails")
            return "ok"

        out = []

        def caller():
            try:
                out.append(sf.do("k", build))
            except RuntimeError as e:
                out.append(("error", str(e)))

        ts = [threading.Thread(target=caller) for _ in range(2)]
        for t in ts:
            t.start()
        time.sleep(0.2)
        gate.set()
        for t in ts:
            t.join(10)
        # exactly one caller saw the error; the other retried as builder
        assert ("error", "first build fails") in out
        assert ("ok", False) in out


# ---------------------------------------------------------------------------
# satellite: per-thread last_stats
# ---------------------------------------------------------------------------


class TestThreadLocalStats:
    def test_two_threads_see_their_own_stats(self):
        db = _mkdb()
        seen = {}
        barrier = threading.Barrier(2)

        def worker(name, lim):
            barrier.wait()
            for _ in range(5):
                res = db.scan("t").limit(lim).execute()
                assert res.num_rows == lim
                seen.setdefault(name, []).append(
                    db.last_stats.rows_scanned)

        t1 = threading.Thread(target=worker, args=("a", 10))
        t2 = threading.Thread(target=worker, args=("b", 20))
        t1.start(); t2.start()
        t1.join(30); t2.join(30)
        # each thread's last_stats reflected ITS query every time: the
        # rows_scanned figures of the two threads never bleed into each
        # other (both scan the full table, so compare via result rows too)
        assert len(seen["a"]) == 5 and len(seen["b"]) == 5
        db.shutdown()

    def test_result_carries_its_own_stats(self):
        db = _mkdb()
        con = db.connect()
        out = {}

        def worker(name, k):
            res = con.query(f"SELECT COUNT(*) AS n FROM t WHERE k = {k}")
            out[name] = res.stats

        t1 = threading.Thread(target=worker, args=("a", 1))
        t2 = threading.Thread(target=worker, args=("b", 2))
        t1.start(); t2.start()
        t1.join(30); t2.join(30)
        assert out["a"] is not None and out["b"] is not None
        assert out["a"] is not out["b"]
        db.shutdown()

    def test_txn_snapshot_copyback_stays_thread_local(self):
        db = _mkdb()
        stats = {}
        barrier = threading.Barrier(2)

        def txn_worker():
            con = db.connect()
            con.begin()
            barrier.wait()
            con.query("SELECT COUNT(*) AS n FROM t")
            stats["txn"] = db.last_stats
            con.rollback()

        def plain_worker():
            con = db.connect()
            barrier.wait()
            con.query("SELECT k FROM t")
            stats["plain"] = db.last_stats

        t1 = threading.Thread(target=txn_worker)
        t2 = threading.Thread(target=plain_worker)
        t1.start(); t2.start()
        t1.join(30); t2.join(30)
        # the session.py:459 copy-back used to clobber the OTHER thread's
        # last_stats; with the thread-local view both remain distinct
        assert stats["txn"] is not stats["plain"]
        assert db.last_stats is None    # main thread never ran a query
        db.shutdown()


# ---------------------------------------------------------------------------
# satellite: atomic try_pin
# ---------------------------------------------------------------------------


class TestTryPin:
    def test_try_pin_reserves_or_fails(self):
        bm = BufferManager(budget=100)
        assert bm.try_pin(60)
        assert not bm.try_pin(60)     # would jointly exceed
        assert bm.try_pin(40)
        bm.unpin(100)
        bm.cleanup()

    def test_check_then_act_race_is_closed(self):
        # hammer try_pin from many threads: the old would_exceed()+pin()
        # pair let two threads pass the check together; the atomic form
        # must keep peak <= budget always
        budget = 10_000
        bm = BufferManager(budget=budget)
        stop = time.monotonic() + 1.0

        def worker():
            while time.monotonic() < stop:
                if bm.try_pin(3000):
                    time.sleep(0)     # widen the race window
                    bm.unpin(3000)

        ts = [threading.Thread(target=worker) for _ in range(8)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(30)
        assert bm.stats.peak <= budget
        assert bm.stats.pinned == 0
        bm.cleanup()

    def test_unlimited_budget_always_pins(self):
        bm = BufferManager()
        assert bm.try_pin(1 << 40)
        assert bm.stats.pinned == 1 << 40
        bm.unpin(1 << 40)
        bm.cleanup()


# ---------------------------------------------------------------------------
# satellite: cleanup deferral
# ---------------------------------------------------------------------------


class TestCleanupDeferral:
    def test_cleanup_defers_while_query_active(self, tmp_path):
        bm = BufferManager(budget=None, spill_dir=str(tmp_path / "sp"))
        bm.begin_query()
        path = bm.new_spill_file("run")
        with open(path, "wb") as f:
            f.write(b"live run file")
        bm.cleanup(wait=0.1)          # must NOT unlink: query in flight
        import os
        assert os.path.exists(path), \
            "cleanup deleted a spill file registered to an active query"
        bm.end_query()                # deferred cleanup fires here
        assert not os.path.exists(path)
        assert bm.active_files == 0

    def test_cleanup_waits_for_drain(self, tmp_path):
        bm = BufferManager(budget=None, spill_dir=str(tmp_path / "sp"))
        bm.begin_query()
        path = bm.new_spill_file("run")
        open(path, "wb").close()

        def finish():
            time.sleep(0.2)
            bm.end_query()

        th = threading.Thread(target=finish)
        th.start()
        bm.cleanup(wait=5.0)          # drains within the wait -> deletes
        th.join(10)
        import os
        assert not os.path.exists(path)

    def test_no_clobber_under_concurrent_spilling_query(self):
        # integration: a spilling query on one thread, shutdown-style
        # cleanup on another — the query must complete with correct
        # results (its run files survive until it drains)
        db = startup(memory_budget=256 * 1024)
        n = 60_000
        db.create_table("big", {
            "k": np.arange(n, dtype=np.int64),      # high-card: spills
            "v": np.ones(n),
        })
        expect = None
        errors = []

        def query():
            nonlocal expect
            try:
                r = db.scan("big").group_by("k").agg(
                    s=("sum", Col("v"))).execute()
                expect = r.num_rows
            except Exception as e:     # noqa: BLE001
                errors.append(e)

        th = threading.Thread(target=query)
        th.start()
        time.sleep(0.05)
        db.buffer_manager.cleanup(wait=0.01)   # racing cleanup: defers
        th.join(60)
        assert not errors, errors
        assert expect == n
        db.buffer_manager.cleanup()
        assert db.buffer_manager.active_files == 0
        db.shutdown()


# ---------------------------------------------------------------------------
# executor integration: admission + concurrency bit-identity
# ---------------------------------------------------------------------------


class TestServingIntegration:
    def test_reservations_reported_per_query(self):
        db = _mkdb(memory_budget=4 * MB)
        _q(db).execute()
        st = db.last_stats
        assert 0 < st.reserved_bytes <= 4 * MB
        assert st.admission_wait_ms == 0.0
        db.shutdown()

    def test_oversized_plan_admits_alone(self):
        # reservations capped at the budget: a plan bigger than the budget
        # (the spill tier's whole reason to exist) runs when idle
        db = _mkdb(memory_budget=64 * 1024)
        r = _q(db).execute()
        assert r.num_rows == 11
        assert db.last_stats.reserved_bytes <= 64 * 1024
        db.shutdown()

    def test_concurrent_mix_bit_identical_to_serial(self):
        db = _mkdb(memory_budget=8 * MB)
        queries = [
            lambda: _q(db).execute(),
            lambda: db.scan("t").filter(Col("k") < 5).group_by("k").agg(
                m=("max", Col("v"))).execute(),
            lambda: db.scan("t").order_by(("v", True), limit=7).execute(),
        ]
        serial = [q().to_pydict() for q in queries]
        out = [[None] * len(queries) for _ in range(4)]
        errors = []

        def worker(slot):
            try:
                for i, q in enumerate(queries):
                    out[slot][i] = q().to_pydict()
            except Exception as e:     # noqa: BLE001
                errors.append(e)

        ts = [threading.Thread(target=worker, args=(s,)) for s in range(4)]
        for t in ts:
            t.start()
        for t in ts:
            t.join(60)
        assert not errors, errors
        for slot in range(4):
            for i, ref in enumerate(serial):
                got = out[slot][i]
                for k in ref:
                    np.testing.assert_array_equal(
                        np.asarray(got[k], dtype=float),
                        np.asarray(ref[k], dtype=float))
        assert db.buffer_manager.stats.peak <= 8 * MB
        assert db.admission_gate.stats.host_reserved_peak <= 8 * MB
        db.shutdown()
