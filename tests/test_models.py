"""Model correctness: attention vs naive oracle, cache consistency, SSM
chunking invariance, MoE routing, spec/param tree congruence."""

import dataclasses
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCH_IDS, get_config
from repro.models.attention import (attn_init, blockwise_attention,
                                    decode_attention_block, init_kv_cache,
                                    prefill_attention_block)
from repro.models.config import ModelConfig
from repro.models.moe import _top_k_dispatch, moe_apply, moe_init
from repro.models.ssm import (mamba1_block, mamba1_init, mamba1_state_init,
                              mamba2_block, mamba2_init, mamba2_state_init)
from repro.models.transformer import (decode_step, forward_train,
                                      init_decode_state, init_model,
                                      model_spec, prefill, train_loss)


def naive_attention(q, k, v, causal=True, window=None):
    B, T, K, G, h = q.shape
    S = k.shape[1]
    s = jnp.einsum("btkgh,bskh->bkgts", q, k) / math.sqrt(h)
    qpos = jnp.arange(T)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgts,bskh->btkgh", w, v)


@pytest.mark.parametrize("T,S,qc,kc,causal,window", [
    (16, 16, 4, 4, True, None),
    (17, 17, 5, 8, True, None),       # non-divisible tiles
    (32, 32, 8, 8, False, None),
    (32, 32, 8, 8, True, 8),          # sliding window
    (8, 24, 4, 8, False, None),       # cross-attention shape
])
def test_blockwise_attention_vs_naive(rng, T, S, qc, kc, causal, window):
    B, K, G, h = 2, 2, 3, 16
    q = jnp.asarray(rng.normal(size=(B, T, K, G, h)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, h)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, h)), jnp.float32)
    got = blockwise_attention(q, k, v, causal=causal, window=window,
                              q_chunk=qc, kv_chunk=kc)
    exp = naive_attention(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(exp),
                               rtol=2e-4, atol=2e-5)


def test_skip_tiles_matches_masked(rng):
    B, T, K, G, h = 1, 32, 1, 2, 8
    q = jnp.asarray(rng.normal(size=(B, T, K, G, h)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, K, h)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, K, h)), jnp.float32)
    a = blockwise_attention(q, k, v, causal=True, window=None,
                            q_chunk=8, kv_chunk=8, skip_tiles=False)
    b = blockwise_attention(q, k, v, causal=True, window=None,
                            q_chunk=8, kv_chunk=8, skip_tiles=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)


def _smoke_cfg(**kw):
    base = dict(name="t", family="dense", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab=128, d_head=8,
                dtype="float32", attn_q_chunk=8, attn_kv_chunk=8,
                remat=False)
    base.update(kw)
    return ModelConfig(**base)


def test_prefill_then_decode_matches_forward(rng):
    """KV-cache correctness: prefill+decode logits == full forward."""
    cfg = _smoke_cfg()
    params = init_model(jax.random.PRNGKey(1), cfg)
    B, S = 2, 12
    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    full_logits, _ = forward_train(params, cfg, {"tokens": toks})
    state = init_decode_state(cfg, B, S + 4)
    pf_logits, state = prefill(params, cfg, state, {"tokens": toks[:, :S]})
    np.testing.assert_allclose(np.asarray(pf_logits[:, 0]),
                               np.asarray(full_logits[:, S - 1]),
                               rtol=2e-3, atol=2e-3)
    dec_logits, state = decode_step(params, cfg, state, toks[:, S:S + 1])
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, S]),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_cache(rng):
    """Ring cache with window w must match full attention restricted to w."""
    cfg = _smoke_cfg(sliding_window=6)
    params = init_model(jax.random.PRNGKey(2), cfg)
    B, S = 1, 16
    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    full_logits, _ = forward_train(params, cfg, {"tokens": toks})
    state = init_decode_state(cfg, B, S + 4)
    _, state = prefill(params, cfg, state, {"tokens": toks[:, :S]})
    dec_logits, _ = decode_step(params, cfg, state, toks[:, S:S + 1])
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(full_logits[:, S]),
                               rtol=2e-3, atol=2e-3)


def test_mamba1_chunk_invariance(rng):
    """Chunked scan == different chunk size (algebraic invariance)."""
    cfg = _smoke_cfg(family="ssm", ssm_state=4, ssm_version=1, ssm_chunk=4)
    p = mamba1_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y1, s1 = mamba1_block(p, x, cfg)
    y2, s2 = mamba1_block(p, x, dataclasses.replace(cfg, ssm_chunk=16))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s1["ssm"]), np.asarray(s2["ssm"]),
                               rtol=1e-4, atol=1e-5)


def test_mamba1_decode_matches_train(rng):
    """Step-by-step decode must reproduce the chunked training output."""
    cfg = _smoke_cfg(family="ssm", ssm_state=4, ssm_version=1, ssm_chunk=4)
    p = mamba1_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 8, cfg.d_model)), jnp.float32)
    y_train, _ = mamba1_block(p, x, cfg)
    st = mamba1_state_init(cfg, 1, jnp.float32)
    outs = []
    for t in range(8):
        y, st = mamba1_block(p, x[:, t:t + 1], cfg, state=st)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               rtol=1e-3, atol=1e-4)


def test_mamba2_chunk_invariance_and_decode(rng):
    cfg = _smoke_cfg(family="ssm", ssm_state=8, ssm_version=2,
                     ssm_head_dim=8, ssm_chunk=4)
    p = mamba2_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 12, cfg.d_model)), jnp.float32)
    y1, s1 = mamba2_block(p, x, cfg)
    y2, s2 = mamba2_block(p, x, dataclasses.replace(cfg, ssm_chunk=12))
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-3, atol=1e-4)
    st = mamba2_state_init(cfg, 1, jnp.float32)
    outs = []
    for t in range(12):
        y, st = mamba2_block(p, x[:, t:t + 1], cfg, state=st)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_dec),
                               rtol=1e-3, atol=1e-4)


def test_moe_all_tokens_routed_with_ample_capacity(rng):
    cfg = _smoke_cfg(family="moe", n_experts=4, top_k=2,
                     capacity_factor=4.0, router_group_tokens=32)
    gates = jax.nn.softmax(
        jnp.asarray(rng.normal(size=(2, 32, 4)), jnp.float32), -1)
    combine, dispatch = _top_k_dispatch(gates, 2, capacity=64)
    # every token holds exactly top_k dispatch slots
    per_token = np.asarray(dispatch.sum(axis=(2, 3)))
    assert (per_token == 2).all()
    # combine weights equal the gate mass of the chosen experts
    w = np.asarray(combine.sum(axis=(2, 3)))
    assert (w <= 1.0 + 1e-5).all() and (w > 0).all()


def test_moe_capacity_drops_overflow(rng):
    cfg = _smoke_cfg(family="moe", n_experts=2, top_k=1,
                     capacity_factor=0.1, router_group_tokens=64)
    p = moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.normal(size=(1, 64, cfg.d_model)), jnp.float32)
    y, aux = moe_apply(p, x, cfg)
    assert y.shape == x.shape
    assert np.isfinite(np.asarray(aux))
    # dropped tokens produce zero output rows; some must have been dropped
    rows = np.abs(np.asarray(y[0])).sum(axis=-1)
    assert (rows == 0).sum() > 0


def test_param_tree_matches_spec_tree():
    from jax.sharding import PartitionSpec
    for arch in ARCH_IDS:
        cfg = get_config(arch).smoke()
        params = jax.eval_shape(
            lambda c=cfg: init_model(jax.random.PRNGKey(0), c))
        spec = model_spec(cfg)
        flat_p = jax.tree_util.tree_structure(params)
        flat_s = jax.tree_util.tree_structure(
            jax.tree.map(lambda s: 0, spec,
                         is_leaf=lambda x: isinstance(x, PartitionSpec)))
        assert flat_p == flat_s, arch


def test_tp_pad_counts():
    cfg = get_config("qwen2_5_14b")
    assert cfg.n_kv_eff == cfg.n_kv_heads        # no pad by default
    padded = dataclasses.replace(cfg, tp_pad=16)
    assert padded.n_kv_eff == 16
    assert padded.n_heads_eff == 16 * cfg.q_per_kv
    seam = dataclasses.replace(get_config("seamless_m4t_large_v2"),
                               tp_pad=16)
    assert seam.vocab_eff % 16 == 0 and seam.vocab_eff >= seam.vocab


def test_train_loss_decreases(rng):
    cfg = _smoke_cfg()
    from repro.train.optimizer import AdamWConfig
    from repro.train.train_step import make_train_step
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-2,
                                                    warmup_steps=1,
                                                    total_steps=50)))
    params = init_model(jax.random.PRNGKey(0), cfg)
    from repro.train.optimizer import init_opt_state
    opt = init_opt_state(params)
    toks = rng.integers(0, cfg.vocab, (4, 17)).astype(np.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    losses = []
    for _ in range(12):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]    # memorizes a fixed batch
