import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.core import startup


@pytest.fixture
def db():
    return startup()


@pytest.fixture
def rng():
    return np.random.default_rng(0)


@pytest.fixture(scope="session", autouse=True)
def _lock_order_witness():
    """Opt-in lock-order witness (REPRO_WITNESS=1): instrument every
    Database built during the session plus the module-level locks, and
    fail the run at teardown on acquisition-order cycles or blocking
    condition waits taken while other witnessed locks are held."""
    if os.environ.get("REPRO_WITNESS") != "1":
        yield
        return
    from repro.analysis import witness as wmod
    w = wmod.LockWitness()
    wmod.install(w)
    yield
    wmod.uninstall()
    sys.stderr.write("\n" + w.report() + "\n")
    w.assert_ok()
