import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

from repro.core import startup


@pytest.fixture
def db():
    return startup()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
