"""SQL parser + optimizer passes."""

import numpy as np
import pytest

from repro.core import Col, startup
from repro.core.optimizer import fold_expr, optimize, split_conjuncts
from repro.core.expression import BinOp, Lit
from repro.core.relalg import (AggregateNode, FilterNode, JoinNode,
                               ProjectNode, ScanNode, walk)


@pytest.fixture
def sdb(rng):
    db = startup()
    n = 1000
    db.create_table("orders", {
        "o_id": np.arange(n, dtype=np.int64),
        "o_cust": rng.integers(0, 100, n).astype(np.int64),
        "o_total": rng.uniform(1, 1000, n),
        "o_status": np.asarray(["A", "B", "C"], dtype=object)[
            rng.integers(0, 3, n)],
    })
    db.create_table("cust", {
        "c_id": np.arange(100, dtype=np.int64),
        "c_region": np.asarray(["EU", "US"], dtype=object)[
            rng.integers(0, 2, 100)],
    })
    return db


def test_sql_basic_agg(sdb):
    out = sdb.connect().query(
        "SELECT o_status, count(*) n, avg(o_total) a FROM orders "
        "GROUP BY o_status ORDER BY o_status").to_pydict()
    assert list(out["o_status"]) == ["A", "B", "C"]
    assert sum(out["n"]) == 1000


def test_sql_comma_join_equals_builder(sdb):
    sql = sdb.connect().query(
        "SELECT c_region, sum(o_total) s FROM orders, cust "
        "WHERE o_cust = c_id GROUP BY c_region ORDER BY c_region"
    ).to_pydict()
    built = (sdb.scan("orders").join(sdb.scan("cust"), left_on="o_cust",
                                     right_on="c_id")
             .group_by("c_region").agg(s=("sum", "o_total"))
             .order_by("c_region").execute().to_pydict())
    np.testing.assert_allclose(sql["s"], built["s"])


def test_sql_having(sdb):
    out = sdb.connect().query(
        "SELECT o_cust, count(*) n FROM orders GROUP BY o_cust "
        "HAVING count(*) > 12 ORDER BY n DESC").to_pydict()
    assert all(n > 12 for n in out["n"])


def test_sql_distinct(sdb):
    out = sdb.connect().query(
        "SELECT DISTINCT o_status FROM orders ORDER BY o_status"
    ).to_pydict()
    assert list(out["o_status"]) == ["A", "B", "C"]


def test_sql_star(sdb):
    out = sdb.connect().query("SELECT * FROM cust LIMIT 3").to_pydict()
    assert set(out) == {"c_id", "c_region"}


def test_sql_case_expression(sdb):
    out = sdb.connect().query(
        "SELECT sum(CASE WHEN o_total > 500 THEN 1 ELSE 0 END) big "
        "FROM orders").to_pydict()
    direct = sdb.connect().query(
        "SELECT count(*) n FROM orders WHERE o_total > 500").to_pydict()
    assert out["big"][0] == direct["n"][0]


def test_sql_errors(sdb):
    from repro.core.sqlparser import SQLError
    con = sdb.connect()
    with pytest.raises(SQLError):
        con.query("SELECT FROM orders")
    with pytest.raises(SQLError):
        con.query("SELECT o_id FROM nonexistent")


# ---- optimizer ------------------------------------------------------------


def test_constant_folding():
    e = fold_expr(BinOp("*", Lit(3), BinOp("+", Lit(1), Lit(1))))
    assert isinstance(e, Lit) and e.value == 6


def test_split_conjuncts():
    e = (Col("a") > 1) & ((Col("b") > 2) & (Col("c") > 3))
    assert len(split_conjuncts(e)) == 3


def test_filter_pushdown_through_join(sdb):
    q = (sdb.scan("orders").join(sdb.scan("cust"), left_on="o_cust",
                                 right_on="c_id")
         .filter((Col("o_total") > 100) & (Col("c_region") == "EU")))
    plan = optimize(q.plan, sdb.catalog)
    # both conjuncts must sit below the join
    for node in walk(plan):
        if isinstance(node, JoinNode):
            sides = [node.left, node.right]
            assert any(isinstance(s, FilterNode) for s in sides)
            break
    else:
        pytest.fail("no join in plan")


def test_projection_pruning_reaches_scan(sdb):
    q = sdb.scan("orders").group_by("o_status").agg(n=("count", None))
    plan = optimize(q.plan, sdb.catalog)
    scans = [n for n in walk(plan) if isinstance(n, ScanNode)]
    assert scans and set(scans[0].columns) == {"o_status"}


def test_join_sides_swap_by_cardinality(sdb):
    # orders (1000) joined as left -> optimizer keeps big side left
    # (build on the small side)
    q = sdb.scan("cust").join(sdb.scan("orders"), left_on="c_id",
                              right_on="o_cust")
    plan = optimize(q.plan, sdb.catalog)
    join = next(n for n in walk(plan) if isinstance(n, JoinNode))
    left_tables = [n.table for n in walk(join.left)
                   if isinstance(n, ScanNode)]
    assert "orders" in left_tables


def test_pushdown_preserves_results(sdb):
    q = (sdb.scan("orders").join(sdb.scan("cust"), left_on="o_cust",
                                 right_on="c_id")
         .filter((Col("o_total") > 100) & (Col("c_region") == "EU"))
         .group_by("o_status").agg(n=("count", None), s=("sum", "o_total"))
         .order_by("o_status"))
    a = q.execute(do_optimize=True).to_pydict()
    b = q.execute(do_optimize=False).to_pydict()
    np.testing.assert_allclose(a["s"], b["s"])
    assert a["n"].tolist() == b["n"].tolist()
