"""Storage model: sentinels, dates, decimals, string heaps, columns."""

import numpy as np
import pytest

from repro.core.column import Column, StringHeap
from repro.core.types import (DBType, NULL_SENTINEL, date_from_string,
                              date_to_string, date_year, decimal_decode,
                              decimal_encode, null_mask)


def test_null_sentinels_are_in_domain():
    assert NULL_SENTINEL[DBType.INT32] == -(2 ** 31)
    assert NULL_SENTINEL[DBType.INT64] == -(2 ** 63)
    assert np.isnan(NULL_SENTINEL[DBType.FLOAT64])


def test_null_mask_int():
    v = np.array([1, NULL_SENTINEL[DBType.INT32], 3], dtype=np.int32)
    assert null_mask(v, DBType.INT32).tolist() == [False, True, False]


def test_null_mask_float_nan():
    v = np.array([1.0, np.nan, 3.0])
    assert null_mask(v, DBType.FLOAT64).tolist() == [False, True, False]


def test_date_roundtrip():
    days = date_from_string(["1992-01-01", "1998-12-31", "1970-01-01"])
    assert days[2] == 0
    back = date_to_string(days)
    assert list(back) == ["1992-01-01", "1998-12-31", "1970-01-01"]
    assert date_year(days).tolist() == [1992, 1998, 1970]


def test_decimal_roundtrip():
    enc = decimal_encode([1.23, -4.56, 0.0], 2)
    assert enc.dtype == np.int64
    assert enc.tolist() == [123, -456, 0]
    np.testing.assert_allclose(decimal_decode(enc, 2), [1.23, -4.56, 0.0])


def test_string_heap_order_preserving():
    heap, codes = StringHeap.encode(["pear", "apple", None, "pear", "fig"])
    # code 0 = NULL; codes sorted lexicographically
    assert codes[2] == 0
    decoded = heap.decode(codes)
    assert decoded[0] == "pear" and decoded[1] == "apple"
    # order preservation: apple < fig < pear
    assert codes[1] < codes[4] < codes[0]
    # duplicate elimination: 'pear' appears once
    assert list(heap.values[1:]).count("pear") == 1


def test_string_heap_bounds():
    heap, codes = StringHeap.encode(["b", "d", "f"])
    assert heap.code_of("d") == codes[1]
    assert heap.code_of("zzz") == -1
    assert heap.lower_bound("c") == codes[1]       # first >= 'c' is 'd'
    assert heap.upper_bound("d") == codes[1] + 1


def test_string_heap_merge_recode():
    heap, codes = StringHeap.encode(["m", "a"])
    new_heap, recode, new_codes = heap.merge(["z", "a", None])
    # old codes remap and stay order preserving
    old = new_heap.decode(recode[codes])
    assert list(old) == ["m", "a"]
    assert new_heap.decode(new_codes)[0] == "z"
    assert new_codes[2] == 0


def test_string_heap_merge_empty_self():
    """Merging into an empty heap adopts the incoming dictionary whole;
    the recode map still sends NULL to NULL."""
    heap = StringHeap()
    assert len(heap) == 1                      # only the NULL placeholder
    new_heap, recode, new_codes = heap.merge(["b", None, "a", "b"])
    assert [str(v) for v in new_heap.values[1:]] == ["a", "b"]
    assert recode[0] == 0
    assert list(new_codes) == [2, 0, 1, 2]


def test_string_heap_merge_all_null_input():
    """An all-NULL merge adds nothing: the heap object itself is returned
    (no re-sort), the recode map is the identity, and every new code is 0."""
    heap, _ = StringHeap.encode(["x", "y"])
    new_heap, recode, new_codes = heap.merge([None, None, None])
    assert new_heap is heap
    assert list(recode) == [0, 1, 2]
    assert list(new_codes) == [0, 0, 0]


def test_string_heap_merge_present_values_o1_path():
    """Appending only already-present values must not rebuild the heap:
    the same object comes back (O(1) in heap size — no global re-sort),
    recode is the identity, and the new codes hit the existing entries."""
    heap, _ = StringHeap.encode(["cc", "aa", "bb"])
    new_heap, recode, new_codes = heap.merge(["bb", None, "aa", "bb"])
    assert new_heap is heap
    assert list(recode) == [0, 1, 2, 3]
    assert list(new_codes) == [heap.code_of("bb"), 0,
                               heap.code_of("aa"), heap.code_of("bb")]


def test_string_heap_merge_keeps_sorted_order():
    """After any merge the heap stays sorted and the recode map is strictly
    increasing on non-NULL codes — i.e. merge preserves code order, so
    range predicates and sorts on recoded columns stay valid."""
    heap, codes = StringHeap.encode(["delta", "alpha", "mike", "alpha"])
    new_heap, recode, new_codes = heap.merge(
        ["zulu", "bravo", "alpha", None, "echo"])
    vals = [str(v) for v in new_heap.values[1:]]
    assert vals == sorted(vals)
    assert all(np.diff(recode[1:]) > 0)        # order-preserving recode
    # both sides decode to their original strings through the merged heap
    assert list(new_heap.decode(recode[codes])) \
        == ["delta", "alpha", "mike", "alpha"]
    assert [None if c == 0 else str(new_heap.values[c])
            for c in new_codes] == ["zulu", "bravo", "alpha", None, "echo"]


def test_string_heap_fingerprint_content_equality():
    """Separately-built heaps with identical contents share a fingerprint;
    any value or order difference changes it."""
    a, _ = StringHeap.encode(["p", "q", None, "p"])
    b, _ = StringHeap.encode(["q", None, "p"])
    assert a is not b
    assert a.content_equal(b) and b.content_equal(a)
    assert a.fingerprint() == b.fingerprint()
    c, _ = StringHeap.encode(["p", "q", "r"])
    assert not a.content_equal(c)
    assert not a.content_equal(None)


def test_column_from_values_with_nulls():
    c = Column.from_values([1, None, 3], DBType.INT64)
    assert c.nulls().tolist() == [False, True, False]
    out = c.to_numpy()
    assert out[1] is None and out[0] == 1


def test_column_varchar_roundtrip():
    c = Column.from_values(["x", None, "y", "x"], DBType.VARCHAR)
    out = c.to_numpy()
    assert list(out) == ["x", None, "y", "x"]


def test_column_decimal():
    c = Column.from_values([1.25, 3.5], DBType.DECIMAL, scale=2)
    assert c.data.dtype == np.int64
    np.testing.assert_allclose(c.to_numpy(), [1.25, 3.5])


def test_column_date_from_strings():
    c = Column.from_values(["1995-06-17", None], DBType.DATE)
    assert c.nulls().tolist() == [False, True]
    assert c.data[0] == int(date_from_string("1995-06-17"))


def test_column_append_varchar_merges_heaps():
    a = Column.from_values(["b", "a"], DBType.VARCHAR)
    b = Column.from_values(["c", "a"], DBType.VARCHAR)
    c = a.append(b)
    assert list(c.to_numpy()) == ["b", "a", "c", "a"]
    # still order-preserving after merge
    codes = c.data
    assert codes[1] < codes[0] < codes[2]


def test_column_take():
    c = Column.from_values([10, 20, 30], DBType.INT64)
    assert c.take(np.array([2, 0])).to_numpy().tolist() == [30, 10]


def test_column_device_cache_and_evict():
    c = Column.from_values(np.arange(8, dtype=np.int64), DBType.INT64)
    d1 = c.device()
    d2 = c.device()
    assert d1 is d2                    # page-in cached
    c.evict()
    assert c._device is None
