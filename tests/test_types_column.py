"""Storage model: sentinels, dates, decimals, string heaps, columns."""

import numpy as np
import pytest

from repro.core.column import Column, StringHeap
from repro.core.types import (DBType, NULL_SENTINEL, date_from_string,
                              date_to_string, date_year, decimal_decode,
                              decimal_encode, null_mask)


def test_null_sentinels_are_in_domain():
    assert NULL_SENTINEL[DBType.INT32] == -(2 ** 31)
    assert NULL_SENTINEL[DBType.INT64] == -(2 ** 63)
    assert np.isnan(NULL_SENTINEL[DBType.FLOAT64])


def test_null_mask_int():
    v = np.array([1, NULL_SENTINEL[DBType.INT32], 3], dtype=np.int32)
    assert null_mask(v, DBType.INT32).tolist() == [False, True, False]


def test_null_mask_float_nan():
    v = np.array([1.0, np.nan, 3.0])
    assert null_mask(v, DBType.FLOAT64).tolist() == [False, True, False]


def test_date_roundtrip():
    days = date_from_string(["1992-01-01", "1998-12-31", "1970-01-01"])
    assert days[2] == 0
    back = date_to_string(days)
    assert list(back) == ["1992-01-01", "1998-12-31", "1970-01-01"]
    assert date_year(days).tolist() == [1992, 1998, 1970]


def test_decimal_roundtrip():
    enc = decimal_encode([1.23, -4.56, 0.0], 2)
    assert enc.dtype == np.int64
    assert enc.tolist() == [123, -456, 0]
    np.testing.assert_allclose(decimal_decode(enc, 2), [1.23, -4.56, 0.0])


def test_string_heap_order_preserving():
    heap, codes = StringHeap.encode(["pear", "apple", None, "pear", "fig"])
    # code 0 = NULL; codes sorted lexicographically
    assert codes[2] == 0
    decoded = heap.decode(codes)
    assert decoded[0] == "pear" and decoded[1] == "apple"
    # order preservation: apple < fig < pear
    assert codes[1] < codes[4] < codes[0]
    # duplicate elimination: 'pear' appears once
    assert list(heap.values[1:]).count("pear") == 1


def test_string_heap_bounds():
    heap, codes = StringHeap.encode(["b", "d", "f"])
    assert heap.code_of("d") == codes[1]
    assert heap.code_of("zzz") == -1
    assert heap.lower_bound("c") == codes[1]       # first >= 'c' is 'd'
    assert heap.upper_bound("d") == codes[1] + 1


def test_string_heap_merge_recode():
    heap, codes = StringHeap.encode(["m", "a"])
    new_heap, recode, new_codes = heap.merge(["z", "a", None])
    # old codes remap and stay order preserving
    old = new_heap.decode(recode[codes])
    assert list(old) == ["m", "a"]
    assert new_heap.decode(new_codes)[0] == "z"
    assert new_codes[2] == 0


def test_column_from_values_with_nulls():
    c = Column.from_values([1, None, 3], DBType.INT64)
    assert c.nulls().tolist() == [False, True, False]
    out = c.to_numpy()
    assert out[1] is None and out[0] == 1


def test_column_varchar_roundtrip():
    c = Column.from_values(["x", None, "y", "x"], DBType.VARCHAR)
    out = c.to_numpy()
    assert list(out) == ["x", None, "y", "x"]


def test_column_decimal():
    c = Column.from_values([1.25, 3.5], DBType.DECIMAL, scale=2)
    assert c.data.dtype == np.int64
    np.testing.assert_allclose(c.to_numpy(), [1.25, 3.5])


def test_column_date_from_strings():
    c = Column.from_values(["1995-06-17", None], DBType.DATE)
    assert c.nulls().tolist() == [False, True]
    assert c.data[0] == int(date_from_string("1995-06-17"))


def test_column_append_varchar_merges_heaps():
    a = Column.from_values(["b", "a"], DBType.VARCHAR)
    b = Column.from_values(["c", "a"], DBType.VARCHAR)
    c = a.append(b)
    assert list(c.to_numpy()) == ["b", "a", "c", "a"]
    # still order-preserving after merge
    codes = c.data
    assert codes[1] < codes[0] < codes[2]


def test_column_take():
    c = Column.from_values([10, 20, 30], DBType.INT64)
    assert c.take(np.array([2, 0])).to_numpy().tolist() == [30, 10]


def test_column_device_cache_and_evict():
    c = Column.from_values(np.arange(8, dtype=np.int64), DBType.INT64)
    d1 = c.device()
    d2 = c.device()
    assert d1 is d2                    # page-in cached
    c.evict()
    assert c._device is None
