"""Per-assigned-architecture smoke tests (reduced same-family configs):
one forward/train step + one decode step on CPU, asserting output shapes
and no NaNs.  Full configs are exercised only via the dry-run."""

import numpy as np
import pytest

import jax

from repro.configs.registry import ARCH_IDS, cells, get_config
from repro.models.transformer import (decode_step, forward_train,
                                      init_decode_state, init_model,
                                      train_loss)
from repro.train.optimizer import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step


def _batch(cfg, rng, B=2, T=16):
    batch = {"labels": rng.integers(0, cfg.vocab, (B, T)).astype(np.int32)}
    if cfg.embeds_input:
        batch["embeds"] = rng.normal(size=(B, T, cfg.d_model)).astype(
            np.float32)
        batch["tokens"] = np.zeros((B, T), np.int32)
    else:
        batch["tokens"] = rng.integers(0, cfg.vocab, (B, T)).astype(
            np.int32)
    if cfg.family == "encdec":
        batch["src_embeds"] = rng.normal(size=(B, T, cfg.d_model)).astype(
            np.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_no_nan(arch, rng):
    cfg = get_config(arch).smoke()
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = _batch(cfg, rng)
    logits, aux = forward_train(params, cfg, batch)
    assert logits.shape == (2, 16, cfg.vocab_eff)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_train_step(arch, rng):
    cfg = get_config(arch).smoke()
    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params)
    step = make_train_step(cfg, AdamWConfig(warmup_steps=1, total_steps=10))
    params2, opt2, metrics = step(params, opt, _batch(cfg, rng))
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(params2)[0]
    assert not np.allclose(np.asarray(l0, np.float32),
                           np.asarray(l1, np.float32))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_one_decode_step(arch, rng):
    cfg = get_config(arch).smoke()
    params = init_model(jax.random.PRNGKey(0), cfg)
    B = 2
    state = init_decode_state(cfg, B, 32, mem_len=8)
    if cfg.family == "encdec":
        state["mem"] = rng.normal(size=(B, 8, cfg.d_model)).astype(
            np.float32)
    tok = np.ones((B, 1), np.int32)
    logits, state2 = decode_step(params, cfg, state, tok)
    assert logits.shape == (B, 1, cfg.vocab_eff)
    assert not np.isnan(np.asarray(logits, np.float32)).any()
    # a second step advances the cache index / state
    logits2, _ = decode_step(params, cfg, state2, tok)
    assert not np.isnan(np.asarray(logits2, np.float32)).any()


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_assigned_config_numbers(arch):
    """The full configs carry the exact assigned architecture numbers."""
    cfg = get_config(arch)
    expected = {
        "qwen2_5_14b": (48, 5120, 40, 8, 13824, 152064),
        "phi3_medium_14b": (40, 5120, 40, 10, 17920, 100352),
        "h2o_danube3_4b": (24, 3840, 32, 8, 10240, 32000),
        "deepseek_7b": (30, 4096, 32, 32, 11008, 102400),
        "falcon_mamba_7b": (64, 4096, 1, 1, 0, 65024),
        "llava_next_34b": (60, 7168, 56, 8, 20480, 64000),
        "seamless_m4t_large_v2": (24, 1024, 16, 16, 8192, 256206),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "moonshot_v1_16b_a3b": (48, 2048, 16, 16, 1408, 163840),
        "zamba2_2_7b": (54, 2560, 32, 32, 10240, 32000),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expected


def test_cell_skips_documented():
    """40 assigned cells = 33 dry-run cells + 7 long_500k skips."""
    total = sum(len(cells(a)) for a in ARCH_IDS)
    assert total == 33
    long_archs = {a for a in ARCH_IDS
                  if any(c.name == "long_500k" for c in cells(a))}
    assert long_archs == {"falcon_mamba_7b", "zamba2_2_7b",
                          "h2o_danube3_4b"}


def test_moe_param_counts_match_assignment():
    dbrx = get_config("dbrx_132b")
    assert dbrx.n_experts == 16 and dbrx.top_k == 4
    assert 120e9 < dbrx.param_count() < 145e9          # ~132B
    moon = get_config("moonshot_v1_16b_a3b")
    assert moon.n_experts == 64 and moon.top_k == 6
    assert moon.active_param_count() < 0.2 * moon.param_count()
